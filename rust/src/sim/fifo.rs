//! The small output-decoupling FIFO (paper §5.3.2).
//!
//! "Instead of halting the computation immediately upon back-pressure, the
//! computation is allowed to proceed for a few cycles while a small
//! temporary FIFO buffer captures the produced output."

use std::collections::VecDeque;

/// Validate a FIFO capacity before construction. Depth 0 is an API error
/// ([`Fifo::new`] would panic): every sim entry point funnels through this
/// check so a bad [`SimOptions::fifo_depth`](crate::eval::SimOptions)
/// surfaces as a structured `Result`, never a panic — regression-tested in
/// `tests/sim_properties.rs`.
pub fn ensure_depth(depth: usize) -> anyhow::Result<()> {
    anyhow::ensure!(depth > 0, "output FIFO depth must be at least 1 (got 0)");
    Ok(())
}

/// Bounded FIFO with occupancy tracking.
#[derive(Debug, Clone)]
pub struct Fifo<T> {
    cap: usize,
    q: VecDeque<T>,
    /// High-water mark (for EXPERIMENTS.md occupancy stats).
    pub max_occupancy: usize,
}

impl<T> Fifo<T> {
    pub fn new(cap: usize) -> Fifo<T> {
        assert!(cap > 0, "FIFO capacity must be positive");
        Fifo { cap, q: VecDeque::with_capacity(cap), max_occupancy: 0 }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.q.len() >= self.cap
    }

    /// Free slots.
    pub fn room(&self) -> usize {
        self.cap - self.q.len()
    }

    pub fn push(&mut self, v: T) {
        assert!(!self.is_full(), "FIFO overflow");
        self.q.push_back(v);
        self.max_occupancy = self.max_occupancy.max(self.q.len());
    }

    pub fn pop(&mut self) -> Option<T> {
        self.q.pop_front()
    }

    pub fn front(&self) -> Option<&T> {
        self.q.front()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_and_bounds() {
        let mut f = Fifo::new(2);
        assert!(f.is_empty());
        f.push(1);
        f.push(2);
        assert!(f.is_full());
        assert_eq!(f.room(), 0);
        assert_eq!(f.pop(), Some(1));
        assert_eq!(f.pop(), Some(2));
        assert_eq!(f.pop(), None);
        assert_eq!(f.max_occupancy, 2);
    }

    #[test]
    #[should_panic]
    fn overflow_panics() {
        let mut f = Fifo::new(1);
        f.push(1);
        f.push(2);
    }

    #[test]
    fn depth_one_pop_then_push_same_cycle() {
        // the machine's per-cycle order (§5.3.2): pop first, then push —
        // so a depth-1 FIFO sustains one word per cycle at full.
        let mut f = Fifo::new(1);
        f.push(10);
        for v in 11..20 {
            assert!(f.is_full());
            let got = f.pop().unwrap();
            assert_eq!(got, v - 1);
            f.push(v);
        }
        assert_eq!(f.max_occupancy, 1);
    }

    #[test]
    fn ensure_depth_accepts_one_rejects_zero() {
        assert!(ensure_depth(1).is_ok());
        assert!(ensure_depth(0).unwrap_err().to_string().contains("FIFO depth"));
    }
}
