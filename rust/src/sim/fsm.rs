//! The three-state Mealy machine controlling the MVU stream unit
//! (paper Fig. 7).
//!
//! States: IDLE (reset / backpressure / no input), WRITE (new input data is
//! written to the input buffer *and* presented to the PEs), READ (buffered
//! data is re-used for the remaining neuron folds). Transitions depend on
//! input availability (TVALID), buffer fill (INP_BUF_FULL), computation
//! completion (COMP_DONE) and downstream stall.

/// FSM states, named as in Fig. 7.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FsmState {
    Idle,
    Write,
    Read,
}

/// Mealy inputs sampled each cycle.
#[derive(Debug, Clone, Copy)]
pub struct FsmInputs {
    /// Upstream TVALID: a new input word is offered.
    pub in_valid: bool,
    /// INP_BUF_FULL: all SF words of the current vector are buffered.
    pub inp_buf_full: bool,
    /// COMP_DONE: all NF neuron folds of the current vector consumed.
    pub comp_done: bool,
    /// Downstream stall: the output FIFO cannot absorb further results.
    pub stalled: bool,
}

/// The Mealy machine. `step` returns the next state plus the action for
/// this cycle (consume an input word / read a buffered word / nothing).
#[derive(Debug, Clone)]
pub struct MvuFsm {
    pub state: FsmState,
}

/// What the control unit does in the current cycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FsmAction {
    /// No compute slot this cycle.
    Nothing,
    /// Accept the offered input word: write it to the buffer and present
    /// it to the PEs (WRITE state behaviour).
    ConsumeInput,
    /// Read the next buffered word and present it to the PEs (READ state).
    ReadBuffer,
}

impl Default for MvuFsm {
    fn default() -> Self {
        Self::new()
    }
}

impl MvuFsm {
    pub fn new() -> MvuFsm {
        MvuFsm { state: FsmState::Idle }
    }

    /// One clock cycle: Mealy output (action) + state transition.
    pub fn step(&mut self, i: FsmInputs) -> FsmAction {
        use FsmAction::*;
        use FsmState::*;
        let (action, next) = match self.state {
            Idle => {
                if i.stalled {
                    (Nothing, Idle)
                } else if i.in_valid && (!i.inp_buf_full || i.comp_done) {
                    // new data available and the buffer can take it (still
                    // filling, or the previous vector is fully processed
                    // and will be overwritten) -> start/continue filling
                    (ConsumeInput, Write)
                } else if i.inp_buf_full && !i.comp_done {
                    // buffered vector still has folds to process
                    (ReadBuffer, Read)
                } else {
                    (Nothing, Idle)
                }
            }
            Write => {
                if i.stalled {
                    (Nothing, Idle)
                } else if !i.inp_buf_full && i.in_valid {
                    (ConsumeInput, Write)
                } else if i.inp_buf_full && !i.comp_done {
                    (ReadBuffer, Read)
                } else if i.inp_buf_full && i.comp_done {
                    // NF == 1: vector done exactly as the buffer filled.
                    if i.in_valid {
                        (ConsumeInput, Write)
                    } else {
                        (Nothing, Idle)
                    }
                } else {
                    // waiting for data from the preceding layer
                    (Nothing, Idle)
                }
            }
            Read => {
                if i.stalled {
                    (Nothing, Idle)
                } else if !i.comp_done {
                    (ReadBuffer, Read)
                } else if i.in_valid {
                    // done re-using: next vector starts filling
                    (ConsumeInput, Write)
                } else {
                    (Nothing, Idle)
                }
            }
        };
        self.state = next;
        action
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inp(in_valid: bool, full: bool, done: bool, stalled: bool) -> FsmInputs {
        FsmInputs { in_valid, inp_buf_full: full, comp_done: done, stalled }
    }

    #[test]
    fn starts_idle_moves_to_write_on_valid() {
        let mut f = MvuFsm::new();
        assert_eq!(f.state, FsmState::Idle);
        let a = f.step(inp(true, false, false, false));
        assert_eq!(a, FsmAction::ConsumeInput);
        assert_eq!(f.state, FsmState::Write);
    }

    #[test]
    fn idle_when_no_input() {
        let mut f = MvuFsm::new();
        assert_eq!(f.step(inp(false, false, false, false)), FsmAction::Nothing);
        assert_eq!(f.state, FsmState::Idle);
    }

    #[test]
    fn write_to_read_on_buffer_full() {
        let mut f = MvuFsm::new();
        f.step(inp(true, false, false, false)); // -> Write
        let a = f.step(inp(true, true, false, false)); // buffer filled, folds remain
        assert_eq!(a, FsmAction::ReadBuffer);
        assert_eq!(f.state, FsmState::Read);
    }

    #[test]
    fn read_until_comp_done_then_next_vector() {
        let mut f = MvuFsm::new();
        f.step(inp(true, false, false, false)); // Write
        f.step(inp(true, true, false, false)); // Read
        assert_eq!(f.step(inp(true, true, false, false)), FsmAction::ReadBuffer);
        // comp done + new input -> consume next vector immediately (II=1)
        let a = f.step(inp(true, true, true, false));
        assert_eq!(a, FsmAction::ConsumeInput);
        assert_eq!(f.state, FsmState::Write);
    }

    #[test]
    fn backpressure_forces_idle() {
        let mut f = MvuFsm::new();
        f.step(inp(true, false, false, false)); // Write
        assert_eq!(f.step(inp(true, false, false, true)), FsmAction::Nothing);
        assert_eq!(f.state, FsmState::Idle);
        // recovers once stall clears
        assert_eq!(f.step(inp(true, false, false, false)), FsmAction::ConsumeInput);
        assert_eq!(f.state, FsmState::Write);
    }

    #[test]
    fn starved_write_goes_idle_and_resumes() {
        let mut f = MvuFsm::new();
        f.step(inp(true, false, false, false)); // Write
        assert_eq!(f.step(inp(false, false, false, false)), FsmAction::Nothing);
        assert_eq!(f.state, FsmState::Idle);
        assert_eq!(f.step(inp(true, false, false, false)), FsmAction::ConsumeInput);
    }

    #[test]
    fn idle_resumes_read_of_buffered_vector() {
        // stall during READ drops to IDLE; on recovery the buffered folds
        // must continue, not restart.
        let mut f = MvuFsm::new();
        f.step(inp(true, false, false, false)); // Write
        f.step(inp(true, true, false, false)); // Read
        f.step(inp(true, true, false, true)); // stalled -> Idle
        assert_eq!(f.state, FsmState::Idle);
        assert_eq!(f.step(inp(false, true, false, false)), FsmAction::ReadBuffer);
        assert_eq!(f.state, FsmState::Read);
    }
}
