//! HLS behavioral model of the MVU.
//!
//! Vivado HLS generates a functionally identical, II=1 pipelined kernel
//! from the FINN C++ template. We model it at the fidelity the paper
//! measures it: identical numerics, an II=1 schedule with a slightly
//! different pipeline-fill latency, plus the *structural* properties the
//! estimator consumes (deep register pipelining, mux-network buffer access,
//! BRAM-mapped weight storage — see `estimate/`).
//!
//! Fill model: HLS achieves `slots + 4` cycles for narrow accumulations and
//! one extra register stage once the SIMD adder tree grows past 8 lanes
//! (matching Table 7: layer0/1 = slots+5, layer3 = slots+4).

use anyhow::Result;

use crate::cfg::{LayerParams, ValidatedParams};
use crate::quant::{matvec, Matrix};

use super::clock::SimReport;

/// Behavioral HLS MVU.
#[derive(Debug)]
pub struct HlsMvu {
    params: LayerParams,
    weights: Matrix,
}

impl HlsMvu {
    /// Build from a validated design point (legality already checked once
    /// in `DesignPoint::build`); only the weight shape can still mismatch.
    pub fn new(params: &ValidatedParams, weights: &Matrix) -> Result<HlsMvu> {
        anyhow::ensure!(
            weights.rows == params.matrix_rows() && weights.cols == params.matrix_cols(),
            "weight shape mismatch"
        );
        Ok(HlsMvu { params: params.params().clone(), weights: weights.clone() })
    }

    pub fn params(&self) -> &LayerParams {
        &self.params
    }

    /// Pipeline-fill latency of the generated kernel (see module docs).
    pub fn fill_latency(&self) -> usize {
        if self.params.simd > 8 {
            5
        } else {
            4
        }
    }

    /// Execution cycles for `n_vectors` streamed inputs (II = 1).
    pub fn exec_cycles(&self, n_vectors: usize) -> usize {
        let slots = self.params.synapse_fold() * self.params.neuron_fold();
        slots * n_vectors + self.fill_latency()
    }

    /// Process a batch of input vectors; the schedule is II=1, numerics
    /// identical to the RTL simulator and the reference.
    pub fn run(&self, vectors: &[Vec<i32>]) -> Result<SimReport> {
        let mut outputs = Vec::with_capacity(vectors.len());
        for v in vectors {
            outputs.push(matvec(v, &self.weights, self.params.simd_type)?);
        }
        let slots = self.params.synapse_fold() * self.params.neuron_fold() * vectors.len();
        Ok(SimReport {
            outputs,
            exec_cycles: self.exec_cycles(vectors.len()),
            stall_cycles: 0,
            source_backpressure_cycles: 0,
            slots_consumed: slots,
            fifo_max_occupancy: 0,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cfg::nid_layers;
    use crate::sim::run_mvu;
    use crate::util::rng::Pcg32;

    #[test]
    fn nid_exec_cycles_match_paper_table7() {
        // paper Table 7 HLS execution cycles: 17, 13, 13, 12
        let expect = [17usize, 13, 13, 12];
        for (params, want) in nid_layers().iter().zip(expect) {
            let w = Matrix::zeros(params.matrix_rows(), params.matrix_cols());
            let hls = HlsMvu::new(params, &w).unwrap();
            assert_eq!(hls.exec_cycles(1), want, "{}", params.name);
        }
    }

    #[test]
    fn hls_and_rtl_agree_numerically() {
        let p = crate::cfg::DesignPoint::fc("t")
            .in_features(24)
            .out_features(6)
            .pe(3)
            .simd(8)
            .build()
            .unwrap();
        let mut rng = Pcg32::new(4);
        let w = Matrix::new(
            6,
            24,
            (0..144).map(|_| rng.next_range(16) as i32 - 8).collect(),
        )
        .unwrap();
        let vecs: Vec<Vec<i32>> = (0..3)
            .map(|_| (0..24).map(|_| rng.next_range(16) as i32 - 8).collect())
            .collect();
        let hls = HlsMvu::new(&p, &w).unwrap().run(&vecs).unwrap();
        let rtl = run_mvu(&p, &w, &vecs).unwrap();
        assert_eq!(hls.outputs, rtl.outputs);
        // both II=1: cycle counts within the fill-latency difference
        let diff = hls.exec_cycles.abs_diff(rtl.exec_cycles);
        assert!(diff <= 2, "HLS {} vs RTL {}", hls.exec_cycles, rtl.exec_cycles);
    }
}
