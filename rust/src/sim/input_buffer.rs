//! The MVU input buffer (paper §6.2.1).
//!
//! Depth `SF = K_d^2 * I_c / SIMD`, word width `SIMD * input_bits`. During
//! WRITE the incoming words are stored (and simultaneously presented to
//! the PEs, Fig. 7); during READ the buffered vector is replayed for the
//! remaining neuron folds (Fig. 3). The paper attributes the HLS LUT
//! blow-up to the multiplexer network synthesized for exactly this
//! buffer's access pattern.
//!
//! Stall behaviour: the write (`wr`) and replay (`rd`) pointers are
//! advanced only by `write`/`read_next` and reset only by `restart` — a
//! datapath stall that drops the FSM to IDLE mid-fill or mid-replay leaves
//! both pointers untouched, so the resumed WRITE/READ continues exactly
//! where it stopped (regression-tested at machine level in
//! `tests/sim_properties.rs`).

/// Circular-fill input buffer.
#[derive(Debug, Clone)]
pub struct InputBuffer {
    depth: usize,
    words: Vec<Vec<i32>>,
    /// Number of words of the current vector written so far.
    wr: usize,
    /// Read pointer used during READ replays.
    rd: usize,
}

impl InputBuffer {
    pub fn new(depth: usize) -> InputBuffer {
        InputBuffer { depth, words: vec![Vec::new(); depth], wr: 0, rd: 0 }
    }

    pub fn depth(&self) -> usize {
        self.depth
    }

    /// INP_BUF_FULL (Fig. 7).
    pub fn full(&self) -> bool {
        self.wr == self.depth
    }

    /// Write the next word of the current vector. Returns its slot index.
    /// Slot storage is reused across vectors (no per-write allocation —
    /// §Perf: this sits on the simulator's per-cycle path).
    pub fn write(&mut self, word: &[i32]) -> usize {
        debug_assert!(!self.full(), "write to full input buffer");
        let slot = self.wr;
        self.words[slot].clear();
        self.words[slot].extend_from_slice(word);
        self.wr += 1;
        slot
    }

    /// Read the word at the replay pointer and advance it (wrapping at
    /// depth so successive neuron folds replay the vector in order).
    pub fn read_next(&mut self) -> &[i32] {
        debug_assert!(self.full(), "replay before buffer full");
        let slot = self.rd;
        self.rd = (self.rd + 1) % self.depth;
        &self.words[slot]
    }

    /// Start accepting the next input vector (overwrites in fill order).
    pub fn restart(&mut self) {
        self.wr = 0;
        self.rd = 0;
    }

    /// Peek a slot (used by tests).
    pub fn peek(&self, slot: usize) -> &[i32] {
        &self.words[slot]
    }

    /// Copy the complete buffered vector (words 0..depth concatenated)
    /// into `out`. Only meaningful when the buffer is full — the row
    /// datapath calls this exactly once per vector, at the first last-
    /// synapse-fold slot, where fullness is guaranteed.
    pub fn copy_vector_into(&self, out: &mut Vec<i32>) {
        debug_assert!(self.full(), "vector copy before buffer full");
        out.clear();
        for w in &self.words {
            out.extend_from_slice(w);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fill_then_replay_in_order() {
        let mut b = InputBuffer::new(3);
        assert!(!b.full());
        b.write(&[1]);
        b.write(&[2]);
        b.write(&[3]);
        assert!(b.full());
        assert_eq!(b.read_next(), &[1]);
        assert_eq!(b.read_next(), &[2]);
        assert_eq!(b.read_next(), &[3]);
        // second replay round (another neuron fold)
        assert_eq!(b.read_next(), &[1]);
    }

    #[test]
    fn restart_overwrites() {
        let mut b = InputBuffer::new(2);
        b.write(&[1]);
        b.write(&[2]);
        b.restart();
        assert!(!b.full());
        b.write(&[9]);
        assert_eq!(b.peek(0), &[9]);
        assert_eq!(b.peek(1), &[2]); // old data until overwritten
    }

    #[test]
    fn copy_vector_concatenates_in_write_order() {
        let mut b = InputBuffer::new(3);
        b.write(&[1, 2]);
        b.write(&[3, 4]);
        b.write(&[5, 6]);
        let mut v = vec![99];
        b.copy_vector_into(&mut v);
        assert_eq!(v, vec![1, 2, 3, 4, 5, 6]);
    }

    #[test]
    #[should_panic]
    fn overfill_panics_in_debug() {
        let mut b = InputBuffer::new(1);
        b.write(&[1]);
        b.write(&[2]);
    }
}
