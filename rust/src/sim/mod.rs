//! Cycle-accurate simulator of the paper's MVU (batch + stream units).
//!
//! The simulator reproduces the microarchitecture of §5 at clock-cycle
//! granularity: the three-state Mealy FSM (Fig. 7), the AXI-Stream
//! valid/ready handshake (Tab. 1), the per-PE weight memories (Eq. 2), the
//! input buffer with its write/read re-use schedule (Fig. 3), the PE x SIMD
//! datapath (Figs. 2 and 4) and the output-decoupling FIFO (§5.3.2).
//!
//! Control is cycle-accurate; the datapath is evaluated functionally at the
//! cycle a compute slot is consumed, with a register-stage delay line
//! modeling the pipeline latency.
//!
//! Two kernels implement these semantics (DESIGN.md §Two-kernel
//! simulator):
//!
//!   * [`reference`] — the tick-by-tick oracle: one `step` per clock
//!     cycle, every FSM/FIFO/delay-line event modelled explicitly;
//!   * [`fast`] — the batched production kernel behind [`run_mvu`] /
//!     [`run_mvu_stalled`] / [`run_mvu_fifo`]: quiescent intervals are
//!     skipped in closed form and ideal-flow runs collapse to the blocked
//!     row-major batch evaluation (DESIGN.md §Batched datapath — the
//!     weight matrix walked once per batch, not once per vector),
//!     bit-identical to the oracle (asserted by
//!     `tests/kernel_identity.rs` over the Table 2 grid).
//!
//! Multi-layer chains follow the same split: [`MvuChain`] is the
//! per-cycle oracle, and [`run_chain`] / [`run_chain_stalled`] /
//! [`run_chain_shared`] dispatch to the next-event kernel in
//! [`fast::chain`] (bit-identical, asserted by `tests/chain_identity.rs`
//! over the NID MLP grid).
//!
//! Bump [`SIM_KERNEL_VERSION`] on any change that could alter a
//! simulation report: it is part of every simulation cache key, so stale
//! on-disk entries from an older kernel can never be served as current.

pub mod axis;
pub mod batch_unit;
pub mod chain;
pub mod clock;
pub mod fast;
pub mod fifo;
pub mod fsm;
pub mod hls;
pub mod input_buffer;
pub mod pe;
pub mod reference;
pub mod simd_elem;
pub mod stream_unit;
pub mod swu;
pub mod weight_mem;

pub use axis::{AxisSink, AxisSource, StallPattern};
pub use batch_unit::MvuBatch;
pub use chain::{chain_bottleneck_ii, ChainLayerStats, ChainReport, ChainStage, MvuChain};
pub use clock::{run_mvu, run_mvu_fifo, run_mvu_shared, run_mvu_stalled, SimReport};
pub use fast::chain::{run_chain, run_chain_shared, run_chain_stalled};
pub use fast::SharedWeights;
pub use fsm::{FsmInputs, FsmState, MvuFsm};
pub use hls::HlsMvu;
pub use swu::SlidingWindowUnit;
pub use weight_mem::{PackedWeightMem, WeightMem};

/// Pipeline register stages between compute-slot consumption and the
/// output FIFO (weight/operand register, SIMD product register, adder-tree
/// register, accumulator register). Together with the FIFO->sink handshake
/// this yields the paper's observed fill latency: total cycles =
/// SF * NF * OD^2 + PIPELINE_STAGES + 1 (Table 7: 17 = 12 + 5).
pub const PIPELINE_STAGES: usize = 4;

/// Default output-FIFO depth (paper §5.3.2: "a small temporary FIFO").
pub const DEFAULT_FIFO_DEPTH: usize = 4;

/// Version of the simulation kernel semantics, included in every
/// simulation cache key (`explore::cache`). Version 2 introduced the
/// batched/interval-skipping kernel; version 3 the bit-packed
/// `Xnor`/`BinaryWeights` ideal-flow datapath (DESIGN.md §Packed
/// datapath) **and** the fold-independent stimulus seed
/// (`explore::stimulus_seed`), which changes the canonical stimulus of
/// fold variants; version 4 the next-event chain kernel
/// ([`fast::chain`], DESIGN.md §Chain fast kernel) together with the
/// chain entries the explore cache now stores; version 5 the blocked
/// multi-vector datapath (DESIGN.md §Batched datapath): ideal-flow runs
/// and chain stages evaluate whole batches row-major through the blocked
/// SWAR kernels, and malformed input vectors now return structured
/// errors instead of panicking. Each new kernel is bit-identical to its
/// predecessor where they overlap, but keying the cache on the kernel
/// version means a kernel change can never be served stale results from
/// a previous kernel's on-disk entries.
pub const SIM_KERNEL_VERSION: u32 = 5;
