//! Processing element: SIMD lane bank + reduction + accumulator
//! (paper Fig. 2).
//!
//! The accumulator is only architecturally required for folded designs
//! (SF > 1); the code keeps it uniformly and the estimator decides whether
//! it costs registers.

use crate::cfg::SimdType;

use super::simd_elem::pe_slot;

/// One PE's accumulator state.
#[derive(Debug, Clone, Default)]
pub struct Pe {
    acc: i32,
}

impl Pe {
    pub fn new() -> Pe {
        Pe { acc: 0 }
    }

    /// Consume one compute slot. `first` resets the accumulator (start of
    /// a new output), `last` returns the finished dot product.
    #[inline]
    pub fn slot(
        &mut self,
        x: &[i32],
        w: &[i32],
        ty: SimdType,
        first: bool,
        last: bool,
    ) -> Option<i32> {
        let partial = pe_slot(x, w, ty);
        self.acc = if first { partial } else { self.acc.wrapping_add(partial) };
        last.then_some(self.acc)
    }

    pub fn acc(&self) -> i32 {
        self.acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_across_folds() {
        let mut pe = Pe::new();
        // dot([1,2,3,4],[1,1,1,1]) over two folds of SIMD=2
        assert_eq!(pe.slot(&[1, 2], &[1, 1], SimdType::Standard, true, false), None);
        assert_eq!(pe.slot(&[3, 4], &[1, 1], SimdType::Standard, false, true), Some(10));
        // next output restarts cleanly
        assert_eq!(pe.slot(&[5, 5], &[2, 0], SimdType::Standard, true, true), Some(10));
    }

    #[test]
    fn row_pass_equals_folded_slots() {
        // the fast kernel (`sim::fast`) replaces SF accumulator-bracketed
        // `slot` calls with one `pe_row` pass over the whole matrix row —
        // bit-identical by associativity of wrapping addition.
        use super::super::simd_elem::pe_row;
        let x: Vec<i32> = (0..12).map(|i| i - 6).collect();
        let w: Vec<i32> = (0..12).map(|i| (i % 5) - 2).collect();
        let mut slotted = Pe::new();
        let mut last = None;
        for (s, (xc, wc)) in x.chunks(4).zip(w.chunks(4)).enumerate() {
            last = slotted.slot(xc, wc, SimdType::Standard, s == 0, s == 2);
        }
        assert_eq!(pe_row(&x, &w, SimdType::Standard), last.unwrap());
        assert_eq!(pe_row(&x, &w, SimdType::Standard), slotted.acc());
    }

    #[test]
    fn unfolded_single_slot() {
        let mut pe = Pe::new();
        assert_eq!(pe.slot(&[1, 1, 0, 1], &[1, 0, 0, 1], SimdType::Xnor, true, true), Some(3));
    }
}
