//! The per-cycle oracle kernel.
//!
//! This is the original tick-by-tick driver: one [`MvuBatch::step`] per
//! clock cycle, every FSM transition, delay-line shift and FIFO operation
//! modelled explicitly. It is kept verbatim as the semantic reference —
//! the batched kernel in [`fast`](super::fast) (behind the public
//! [`run_mvu*`](super::run_mvu) entry points) must reproduce its
//! [`SimReport`]s bit-for-bit, which `tests/kernel_identity.rs` asserts
//! over the full Table 2 grid and under random stall patterns.
//!
//! Use this module when auditing cycle-level behaviour or validating a
//! kernel change; use the public entry points for throughput.

use anyhow::{bail, Result};

use crate::cfg::ValidatedParams;
use crate::quant::Matrix;

use super::axis::{AxisSink, AxisSource, StallPattern};
use super::batch_unit::MvuBatch;
use super::clock::SimReport;
use super::{DEFAULT_FIFO_DEPTH, PIPELINE_STAGES};

/// Reference run with ideal stimulus (always-valid source, always-ready
/// sink). See [`super::run_mvu`] for the production entry point.
pub fn run_mvu(
    params: &ValidatedParams,
    weights: &Matrix,
    vectors: &[Vec<i32>],
) -> Result<SimReport> {
    run_mvu_stalled(params, weights, vectors, StallPattern::None, StallPattern::None)
}

/// Reference run with stall patterns on both AXI endpoints.
pub fn run_mvu_stalled(
    params: &ValidatedParams,
    weights: &Matrix,
    vectors: &[Vec<i32>],
    in_stall: StallPattern,
    out_stall: StallPattern,
) -> Result<SimReport> {
    run_mvu_fifo(params, weights, vectors, in_stall, out_stall, DEFAULT_FIFO_DEPTH)
}

/// Full-control reference run: stall patterns plus an explicit output-FIFO
/// depth, simulated one clock cycle at a time.
pub fn run_mvu_fifo(
    params: &ValidatedParams,
    weights: &Matrix,
    vectors: &[Vec<i32>],
    in_stall: StallPattern,
    out_stall: StallPattern,
    fifo_depth: usize,
) -> Result<SimReport> {
    let mut mvu = MvuBatch::with_fifo_depth(params, weights, fifo_depth)?;
    MvuBatch::ensure_vector_shapes(params, vectors)?;
    let words: Vec<Vec<i32>> = vectors
        .iter()
        .flat_map(|v| MvuBatch::vector_to_words(params, v))
        .collect();
    let mut source = AxisSource::new(words, in_stall);
    let mut sink = AxisSink::new(out_stall);

    let expected_words = vectors.len() * params.neuron_fold();
    // generous deadlock bound: ideal cycles x 16 + constant slack
    let max_cycles = params
        .analytic_cycles(PIPELINE_STAGES)
        .saturating_mul(vectors.len().max(1))
        .saturating_mul(16)
        + 4096;

    let mut last_out_cycle = 0usize;
    let mut cycle = 0usize;
    while sink.received.len() < expected_words {
        if cycle > max_cycles {
            bail!(
                "simulation deadlock: {}/{} output words after {} cycles",
                sink.received.len(),
                expected_words,
                cycle
            );
        }
        let has_offer = !source.exhausted() && !source.stalled_now(cycle);
        let ready = sink.ready(cycle);
        let offered: Option<&[i32]> = has_offer.then(|| source.peek());
        let r = mvu.step(offered, ready);
        if r.consumed_input {
            source.accept();
        } else if has_offer {
            source.note_backpressure();
        }
        if let Some(word) = r.emitted {
            sink.push(word, cycle);
            last_out_cycle = cycle;
        }
        cycle += 1;
    }
    if !mvu.drained() {
        bail!("simulation finished with data still in flight");
    }

    let nf = params.neuron_fold();
    let outputs: Vec<Vec<i32>> = sink
        .received
        .chunks(nf)
        .map(|chunk| MvuBatch::words_to_vector(params, chunk))
        .collect();
    let stats = mvu.stats();
    Ok(SimReport {
        outputs,
        exec_cycles: last_out_cycle + 1,
        stall_cycles: stats.stall_cycles,
        source_backpressure_cycles: source.backpressure_cycles,
        slots_consumed: stats.slots_consumed,
        fifo_max_occupancy: mvu.fifo_max_occupancy(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cfg::DesignPoint;
    use crate::quant::matvec;
    use crate::util::rng::Pcg32;

    #[test]
    fn reference_matches_gemm_and_formula() {
        let p = DesignPoint::fc("ref")
            .in_features(16)
            .out_features(8)
            .pe(4)
            .simd(8)
            .build()
            .unwrap();
        let mut rng = Pcg32::new(3);
        let w = Matrix::new(8, 16, (0..128).map(|_| rng.next_range(8) as i32 - 4).collect())
            .unwrap();
        let vecs: Vec<Vec<i32>> = (0..3)
            .map(|_| (0..16).map(|_| rng.next_range(8) as i32 - 4).collect())
            .collect();
        let rep = run_mvu(&p, &w, &vecs).unwrap();
        for (x, y) in vecs.iter().zip(&rep.outputs) {
            assert_eq!(y, &matvec(x, &w, p.simd_type).unwrap());
        }
        let slots = p.synapse_fold() * p.neuron_fold() * 3;
        assert_eq!(rep.exec_cycles, slots + PIPELINE_STAGES + 1);
    }
}
