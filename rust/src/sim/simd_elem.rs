//! SIMD elements and the PE reduction (paper Figs. 2 and 4).
//!
//! A SIMD element combines one input lane with one weight lane; the PE
//! reduces the SIMD outputs with a popcount (1-bit) or an adder tree and
//! accumulates across synapse folds.

use crate::cfg::SimdType;
use crate::quant::pack_bits_into;

/// One SIMD element (Fig. 4): (a) XNOR, (b) +/-x mux, (c) multiplier.
#[inline]
pub fn simd_lane(x: i32, w: i32, ty: SimdType) -> i32 {
    match ty {
        SimdType::Xnor => {
            debug_assert!(x == 0 || x == 1, "xnor input lane must be a bit");
            debug_assert!(w == 0 || w == 1, "xnor weight lane must be a bit");
            i32::from(x == w)
        }
        SimdType::BinaryWeights => {
            debug_assert!(w == 0 || w == 1, "binary weight lane must be a bit");
            if w == 1 {
                x
            } else {
                x.wrapping_neg()
            }
        }
        SimdType::Standard => x.wrapping_mul(w),
    }
}

/// The PE's lane reduction as the RTL structures it: a balanced binary
/// adder tree (the shape the delay estimator's logic-depth model prices).
/// Executable documentation of that structure, held equal to the linear
/// sums the datapath kernels use (`pe_slot`/`pe_row`) by the tests —
/// legitimate because wrapping addition is associative and commutative.
///
/// Implemented as an iterative pairwise reduction over a fixed
/// partial-sum stack (one slot per tree level, like a binary carry
/// chain); the former formulation recursed with two slice splits per
/// level, which is needless call-frame traffic for a model that exists
/// to be read and property-tested against.
pub fn adder_tree(lanes: &[i32]) -> i32 {
    // stack[k] holds the root of a complete 2^k-leaf subtree; pushing a
    // leaf merges same-height subtrees exactly like incrementing a binary
    // counter, so usize::BITS slots cover any slice length (and every
    // shift below stays in range).
    let mut stack = [0i32; usize::BITS as usize];
    let mut count: usize = 0;
    for &v in lanes {
        let mut node = v;
        let mut k = 0;
        while count & (1 << k) != 0 {
            node = stack[k].wrapping_add(node);
            k += 1;
        }
        stack[k] = node;
        count += 1;
    }
    // merge the leftover partials, low (rightmost leaves) to high
    let mut acc = 0i32;
    for (k, partial) in stack.iter().enumerate() {
        if count & (1 << k) != 0 {
            acc = partial.wrapping_add(acc);
        }
    }
    acc
}

/// One PE compute slot: apply the SIMD lanes and reduce.
///
/// §Perf: the match is hoisted out of the lane loop so each variant is a
/// tight, auto-vectorizable kernel (the generic `simd_lane`-per-lane
/// formulation kept LLVM from vectorizing the multiply-accumulate).
#[inline]
pub fn pe_slot(x: &[i32], w: &[i32], ty: SimdType) -> i32 {
    debug_assert_eq!(x.len(), w.len());
    match ty {
        SimdType::Xnor => x
            .iter()
            .zip(w)
            .map(|(&a, &b)| (a == b) as i32)
            .fold(0i32, i32::wrapping_add),
        SimdType::BinaryWeights => x
            .iter()
            .zip(w)
            .map(|(&a, &b)| {
                // w in {0,1}: +x / -x without a branch
                let sign = 2 * b - 1;
                a.wrapping_mul(sign)
            })
            .fold(0i32, i32::wrapping_add),
        SimdType::Standard => x
            .iter()
            .zip(w)
            .map(|(&a, &b)| a.wrapping_mul(b))
            .fold(0i32, i32::wrapping_add),
    }
}

/// A whole weight-matrix row as one fold-block pass: bit-identical to the
/// cycle kernel's slot-by-slot evaluation — [`pe_slot`] per `(nf, sf)`
/// slot, `wrapping_add` across slots — because two's-complement wrapping
/// addition is associative and commutative, so regrouping the lane sum is
/// exact, not approximate. The fixed-width blocks break the sequential
/// accumulator dependency so LLVM vectorizes across the former slot
/// boundaries (§Perf: this is the fast kernel's inner loop).
#[inline]
pub fn pe_row(x: &[i32], w: &[i32], ty: SimdType) -> i32 {
    debug_assert_eq!(x.len(), w.len());
    const BLOCK: usize = 64;
    let mut acc = 0i32;
    let mut i = 0;
    while i + BLOCK <= x.len() {
        acc = acc.wrapping_add(pe_slot(&x[i..i + BLOCK], &w[i..i + BLOCK], ty));
        i += BLOCK;
    }
    acc.wrapping_add(pe_slot(&x[i..], &w[i..], ty))
}

/// XNOR row dot product over pre-packed bits: popcount of the word-wise
/// XNOR — exactly the Fig. 4(a) RTL datapath, 64 lanes per operation.
/// `lanes` is the true row length; both slices are `ceil(lanes/64)`
/// zero-padded words, and the tail mask keeps the padding (which would
/// XNOR to all-ones) out of the count.
///
/// Bit-identical to [`pe_row`]`(.., SimdType::Xnor)`: both produce the
/// agreement count modulo 2^32 (the i32 wrapping sum of `+1`s and the u32
/// wrapping popcount accumulate the same residue).
#[inline]
pub fn pe_row_packed_xnor(x: &[u64], w: &[u64], lanes: usize) -> i32 {
    debug_assert_eq!(x.len(), lanes.div_ceil(64));
    debug_assert_eq!(w.len(), x.len());
    let mut agree = 0u32;
    let full = lanes / 64;
    for i in 0..full {
        agree = agree.wrapping_add((!(x[i] ^ w[i])).count_ones());
    }
    let tail = lanes % 64;
    if tail > 0 {
        let mask = (1u64 << tail) - 1;
        agree = agree.wrapping_add((!(x[full] ^ w[full]) & mask).count_ones());
    }
    agree as i32
}

/// Binary-weight row dot product with the weight row as a sign mask:
/// with S = sum of all lanes and S1 = sum of the lanes whose weight bit
/// is set, `sum(w ? x : -x) = 2*S1 - S` — exact in wrapping i32
/// arithmetic because Z/2^32 is a ring, so it is bit-identical to
/// [`pe_row`]`(.., SimdType::BinaryWeights)`. The caller precomputes
/// `total` (= S) once per input vector and amortizes it over every row.
/// `wmask` is zero-padded past the row length, so the bit scan never
/// indexes beyond `x`.
#[inline]
pub fn pe_row_packed_binary(x: &[i32], wmask: &[u64], total: i32) -> i32 {
    debug_assert_eq!(wmask.len(), x.len().div_ceil(64));
    let mut s1 = 0i32;
    for (wi, &word) in wmask.iter().enumerate() {
        let base = wi * 64;
        let mut m = word;
        while m != 0 {
            let b = m.trailing_zeros() as usize;
            s1 = s1.wrapping_add(x[base + b]);
            m &= m - 1;
        }
    }
    s1.wrapping_add(s1).wrapping_sub(total)
}

/// Blocked multi-vector XNOR row kernel (DESIGN.md §Batched datapath):
/// evaluate one weight row against `out.len()` input vectors in a single
/// pass. `planes` holds the batch as per-vector bit-planes
/// ([`crate::quant::pack_bits_columns`]): vector `b`'s packed bits at
/// words `[b*words_per_vec, (b+1)*words_per_vec)`. The weight word is the
/// OUTER loop — loaded once and reused across all B vectors while
/// register-hot, which is the weight-reuse the per-vector kernel cannot
/// have — and `out[b]` accumulates vector `b`'s agreement count.
///
/// Bit-identical to [`pe_row_packed_xnor`] per vector: both accumulate
/// the same per-word popcounts with wrapping addition, and wrapping
/// addition is associative and commutative, so the word-major regrouping
/// is exact (u32 and i32 wrapping adds are the same bit operation).
#[inline]
pub fn pe_rows_batched_xnor(
    planes: &[u64],
    words_per_vec: usize,
    w: &[u64],
    lanes: usize,
    out: &mut [i32],
) {
    debug_assert_eq!(words_per_vec, lanes.div_ceil(64));
    debug_assert_eq!(w.len(), words_per_vec);
    debug_assert_eq!(planes.len(), out.len() * words_per_vec);
    out.fill(0);
    let full = lanes / 64;
    for (i, &wi) in w.iter().enumerate().take(full) {
        for (b, o) in out.iter_mut().enumerate() {
            let x = planes[b * words_per_vec + i];
            *o = o.wrapping_add((!(x ^ wi)).count_ones() as i32);
        }
    }
    let tail = lanes % 64;
    if tail > 0 {
        let mask = (1u64 << tail) - 1;
        let wi = w[full];
        for (b, o) in out.iter_mut().enumerate() {
            let x = planes[b * words_per_vec + full];
            *o = o.wrapping_add((!(x ^ wi) & mask).count_ones() as i32);
        }
    }
}

/// Blocked multi-vector binary-weight row kernel: one weight-row bit scan
/// serves all B vectors. `xt` is the batch transposed lane-major —
/// `xt[lane * B + b]` is vector `b`'s lane `lane` — so each set weight
/// bit touches B consecutive values (one cache line for small B), and
/// `totals[b]` is vector `b`'s precomputed wrapping lane sum (the `S`
/// term, amortized over every row like the per-vector kernel's `total`).
///
/// Bit-identical to [`pe_row_packed_binary`] per vector: the same set
/// lanes are summed into `s1` (order irrelevant under wrapping addition)
/// and the same `2*S1 - S` identity closes each output.
#[inline]
pub fn pe_rows_batched_binary(
    xt: &[i32],
    batch: usize,
    wmask: &[u64],
    totals: &[i32],
    out: &mut [i32],
) {
    debug_assert_eq!(out.len(), batch);
    debug_assert_eq!(totals.len(), batch);
    debug_assert_eq!(xt.len() % batch.max(1), 0);
    debug_assert_eq!(wmask.len(), (xt.len() / batch.max(1)).div_ceil(64));
    out.fill(0);
    for (wi, &word) in wmask.iter().enumerate() {
        let base = wi * 64;
        let mut m = word;
        while m != 0 {
            let lane = base + m.trailing_zeros() as usize;
            let xs = &xt[lane * batch..(lane + 1) * batch];
            for (o, &x) in out.iter_mut().zip(xs) {
                *o = o.wrapping_add(x);
            }
            m &= m - 1;
        }
    }
    for (o, &t) in out.iter_mut().zip(totals) {
        *o = (*o).wrapping_add(*o).wrapping_sub(t);
    }
}

/// Blocked multi-vector flat row kernel: one [`pe_row`] per vector over
/// the same weight row while it is cache-hot — the `Standard`-type (and
/// unpackable-operand fallback) arm of the blocked traversal. Trivially
/// bit-identical to B independent [`pe_row`] calls.
#[inline]
pub fn pe_rows_batched_flat(vectors: &[Vec<i32>], wrow: &[i32], ty: SimdType, out: &mut [i32]) {
    debug_assert_eq!(vectors.len(), out.len());
    for (o, v) in out.iter_mut().zip(vectors) {
        *o = pe_row(v, wrow, ty);
    }
}

/// Packing wrapper over the SWAR kernels: evaluate one whole row from
/// unpacked lanes, bit-identical to [`pe_row`] for **every** input —
/// operands outside the packable range ({0,1} inputs/weights for Xnor,
/// {0,1} weights for BinaryWeights) fall back to the flat kernel, exactly
/// as the fast simulation kernel does. The hot path packs once per run
/// and calls [`pe_row_packed_xnor`] / [`pe_row_packed_binary`] directly;
/// this form exists for property tests and one-off callers.
pub fn pe_row_packed(x: &[i32], w: &[i32], ty: SimdType) -> i32 {
    debug_assert_eq!(x.len(), w.len());
    let mut xw = Vec::new();
    let mut ww = Vec::new();
    match ty {
        SimdType::Xnor => {
            if pack_bits_into(x, &mut xw).is_err() || pack_bits_into(w, &mut ww).is_err() {
                return pe_row(x, w, ty);
            }
            pe_row_packed_xnor(&xw, &ww, x.len())
        }
        SimdType::BinaryWeights => {
            if pack_bits_into(w, &mut ww).is_err() {
                return pe_row(x, w, ty);
            }
            let total = x.iter().fold(0i32, |a, &v| a.wrapping_add(v));
            pe_row_packed_binary(x, &ww, total)
        }
        SimdType::Standard => pe_row(x, w, ty),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lanes_match_fig4() {
        assert_eq!(simd_lane(1, 1, SimdType::Xnor), 1);
        assert_eq!(simd_lane(0, 1, SimdType::Xnor), 0);
        assert_eq!(simd_lane(0, 0, SimdType::Xnor), 1);
        assert_eq!(simd_lane(5, 1, SimdType::BinaryWeights), 5);
        assert_eq!(simd_lane(5, 0, SimdType::BinaryWeights), -5);
        assert_eq!(simd_lane(-3, 7, SimdType::Standard), -21);
    }

    #[test]
    fn adder_tree_equals_linear_sum() {
        let lanes: Vec<i32> = (-20..30).collect();
        assert_eq!(adder_tree(&lanes), lanes.iter().sum::<i32>());
        assert_eq!(adder_tree(&[]), 0);
        assert_eq!(adder_tree(&[42]), 42);
    }

    #[test]
    fn pe_row_equals_slotwise_accumulation() {
        use crate::util::rng::Pcg32;
        let mut rng = Pcg32::new(42);
        for ty in SimdType::ALL {
            // lengths straddling the block size, including 0 and exact
            // multiples
            for n in [0usize, 1, 7, 63, 64, 65, 128, 200] {
                let bit = matches!(ty, SimdType::Xnor | SimdType::BinaryWeights);
                let x: Vec<i32> = (0..n)
                    .map(|_| {
                        if matches!(ty, SimdType::Xnor) {
                            rng.next_range(2) as i32
                        } else {
                            rng.next_range(15) as i32 - 7
                        }
                    })
                    .collect();
                let w: Vec<i32> = (0..n)
                    .map(|_| {
                        if bit {
                            rng.next_range(2) as i32
                        } else {
                            rng.next_range(15) as i32 - 7
                        }
                    })
                    .collect();
                // slot-wise oracle: arbitrary slot width 8 with remainder
                let mut acc = 0i32;
                let mut i = 0;
                while i < n {
                    let j = (i + 8).min(n);
                    acc = acc.wrapping_add(pe_slot(&x[i..j], &w[i..j], ty));
                    i = j;
                }
                assert_eq!(pe_row(&x, &w, ty), acc, "{ty} n={n}");
            }
        }
    }

    #[test]
    fn pe_slot_matches_reference() {
        use crate::quant::{matvec, Matrix};
        let x = [1, 0, 1, 1];
        let w = Matrix::from_rows(&[vec![1, 1, 0, 1]]).unwrap();
        for ty in SimdType::ALL {
            let expect = matvec(&x, &w, ty).unwrap()[0];
            assert_eq!(pe_slot(&x, w.row(0), ty), expect, "{ty}");
        }
    }

    /// The packed-datapath identity chain on random inputs:
    /// `popcount_xnor_packed` == `pe_slot(.., Xnor)` == `pe_row_packed`
    /// for bit lanes, and `pe_row_packed` == `pe_row` == `pe_slot` on
    /// every type (including wrapping-heavy BinaryWeights operands).
    #[test]
    fn prop_packed_row_kernels_match_pe_slot() {
        use crate::proptest::{check, Config};
        use crate::quant::popcount_xnor_packed;
        check("packed == slot-wise", Config::cases(150), |g| {
            let n = g.usize_in(0, 300);
            for ty in SimdType::ALL {
                let (xlo, xhi) = match ty {
                    SimdType::Xnor => (0, 1),
                    // wide range so 2*S1 - S actually wraps sometimes
                    _ => (i32::MIN / 2, i32::MAX / 2),
                };
                let x: Vec<i32> = (0..n).map(|_| g.i32_in(xlo, xhi)).collect();
                let w: Vec<i32> = (0..n)
                    .map(|_| match ty {
                        SimdType::Standard => g.i32_in(-8, 7),
                        _ => g.i32_in(0, 1),
                    })
                    .collect();
                let by_slot = pe_slot(&x, &w, ty);
                let by_row = pe_row(&x, &w, ty);
                let by_packed = pe_row_packed(&x, &w, ty);
                if by_slot != by_row || by_row != by_packed {
                    return Err(format!(
                        "{ty} n={n}: slot {by_slot} row {by_row} packed {by_packed}"
                    ));
                }
                if matches!(ty, SimdType::Xnor) {
                    let pc = popcount_xnor_packed(&x, &w).map_err(|e| e.to_string())? as i32;
                    if pc != by_slot {
                        return Err(format!("xnor n={n}: popcount {pc} != slot {by_slot}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn pe_row_packed_falls_back_on_unpackable_operands() {
        // a 2 in an xnor/binary operand cannot be bit-packed; the wrapper
        // must agree with pe_row anyway.
        let x = [1, 0, 2, 1];
        let w = [1, 1, 0, 1];
        assert_eq!(pe_row_packed(&x, &w, SimdType::Xnor), pe_row(&x, &w, SimdType::Xnor));
        let wbad = [1, 0, 2, 1];
        let xi = [5, -3, 7, 11];
        assert_eq!(
            pe_row_packed(&xi, &wbad, SimdType::BinaryWeights),
            pe_row(&xi, &wbad, SimdType::BinaryWeights)
        );
    }

    /// The blocked multi-vector kernels are a pure regrouping of the
    /// per-vector kernels: for every batch size (including 0 and 1) and
    /// lane counts straddling the word boundary, batched output `b` must
    /// equal the per-vector packed kernel on vector `b` alone.
    #[test]
    fn prop_batched_rows_match_per_vector_kernels() {
        use crate::proptest::{check, Config};
        use crate::quant::{pack_bits_columns, pack_bits_into};
        check("batched == per-vector", Config::cases(120), |g| {
            let lanes = *g.choose(&[0usize, 1, 5, 63, 64, 65, 130]);
            let batch = *g.choose(&[0usize, 1, 2, 7, 32, 33]);
            // Xnor: bit vectors against one bit weight row.
            let w: Vec<i32> = (0..lanes).map(|_| g.i32_in(0, 1)).collect();
            let vecs: Vec<Vec<i32>> =
                (0..batch).map(|_| (0..lanes).map(|_| g.i32_in(0, 1)).collect()).collect();
            let mut planes = Vec::new();
            pack_bits_columns(&vecs, lanes, &mut planes).map_err(|e| e.to_string())?;
            let mut ww = Vec::new();
            pack_bits_into(&w, &mut ww).map_err(|e| e.to_string())?;
            let mut out = vec![0i32; batch];
            pe_rows_batched_xnor(&planes, lanes.div_ceil(64), &ww, lanes, &mut out);
            for (b, v) in vecs.iter().enumerate() {
                let mut xw = Vec::new();
                pack_bits_into(v, &mut xw).map_err(|e| e.to_string())?;
                let per = pe_row_packed_xnor(&xw, &ww, lanes);
                if out[b] != per {
                    return Err(format!(
                        "xnor lanes={lanes} b={b}: batched {} != per-vector {per}",
                        out[b]
                    ));
                }
            }
            // BinaryWeights: wide signed vectors (wrapping-heavy) against
            // the same bit weight row, lane-major transposed.
            let ivecs: Vec<Vec<i32>> = (0..batch)
                .map(|_| (0..lanes).map(|_| g.i32_in(i32::MIN / 2, i32::MAX / 2)).collect())
                .collect();
            let mut xt = vec![0i32; lanes * batch];
            for (b, v) in ivecs.iter().enumerate() {
                for (lane, &x) in v.iter().enumerate() {
                    xt[lane * batch + b] = x;
                }
            }
            let totals: Vec<i32> = ivecs
                .iter()
                .map(|v| v.iter().fold(0i32, |a, &x| a.wrapping_add(x)))
                .collect();
            let mut bout = vec![0i32; batch];
            pe_rows_batched_binary(&xt, batch, &ww, &totals, &mut bout);
            let mut fout = vec![0i32; batch];
            pe_rows_batched_flat(&ivecs, &w, SimdType::BinaryWeights, &mut fout);
            for (b, v) in ivecs.iter().enumerate() {
                let per = pe_row_packed_binary(v, &ww, totals[b]);
                if bout[b] != per || fout[b] != pe_row(v, &w, SimdType::BinaryWeights) {
                    return Err(format!(
                        "binary lanes={lanes} b={b}: batched {} flat {} per-vector {per}",
                        bout[b], fout[b]
                    ));
                }
            }
            Ok(())
        });
    }

    /// Batched kernels must clear stale accumulator contents: `out` is an
    /// output parameter, not an accumulator across calls.
    #[test]
    fn batched_kernels_reset_output_buffer() {
        let vecs = vec![vec![1, 0, 1], vec![0, 0, 1]];
        let w = [1, 1, 0];
        let mut planes = Vec::new();
        crate::quant::pack_bits_columns(&vecs, 3, &mut planes).unwrap();
        let mut ww = Vec::new();
        crate::quant::pack_bits_into(&w, &mut ww).unwrap();
        let mut out = vec![i32::MIN; 2];
        pe_rows_batched_xnor(&planes, 1, &ww, 3, &mut out);
        pe_rows_batched_xnor(&planes, 1, &ww, 3, &mut out); // second call: same result
        for (b, v) in vecs.iter().enumerate() {
            assert_eq!(out[b], pe_row(v, &w, SimdType::Xnor), "b={b}");
        }
    }

    #[test]
    fn packed_kernels_handle_word_boundaries() {
        // lengths 63/64/65/128/130: full words, exact multiples, tails
        for n in [0usize, 1, 63, 64, 65, 128, 130] {
            let x: Vec<i32> = (0..n).map(|i| ((i * 5) % 3 == 0) as i32).collect();
            let w: Vec<i32> = (0..n).map(|i| ((i * 7) % 2 == 0) as i32).collect();
            assert_eq!(
                pe_row_packed(&x, &w, SimdType::Xnor),
                pe_row(&x, &w, SimdType::Xnor),
                "xnor n={n}"
            );
            let xi: Vec<i32> = (0..n).map(|i| i as i32 * 17 - 40).collect();
            assert_eq!(
                pe_row_packed(&xi, &w, SimdType::BinaryWeights),
                pe_row(&xi, &w, SimdType::BinaryWeights),
                "binary n={n}"
            );
        }
    }
}
