//! SIMD elements and the PE reduction (paper Figs. 2 and 4).
//!
//! A SIMD element combines one input lane with one weight lane; the PE
//! reduces the SIMD outputs with a popcount (1-bit) or an adder tree and
//! accumulates across synapse folds.

use crate::cfg::SimdType;

/// One SIMD element (Fig. 4): (a) XNOR, (b) +/-x mux, (c) multiplier.
#[inline]
pub fn simd_lane(x: i32, w: i32, ty: SimdType) -> i32 {
    match ty {
        SimdType::Xnor => {
            debug_assert!(x == 0 || x == 1, "xnor input lane must be a bit");
            debug_assert!(w == 0 || w == 1, "xnor weight lane must be a bit");
            i32::from(x == w)
        }
        SimdType::BinaryWeights => {
            debug_assert!(w == 0 || w == 1, "binary weight lane must be a bit");
            if w == 1 {
                x
            } else {
                x.wrapping_neg()
            }
        }
        SimdType::Standard => x.wrapping_mul(w),
    }
}

/// The PE's lane reduction: popcount for XNOR, adder tree otherwise.
/// Implemented as a balanced binary tree (matching the logic-depth model
/// in the delay estimator), though integer addition is associative so the
/// result equals a linear sum.
pub fn adder_tree(lanes: &[i32]) -> i32 {
    match lanes.len() {
        0 => 0,
        1 => lanes[0],
        n => {
            let (lo, hi) = lanes.split_at(n / 2);
            adder_tree(lo).wrapping_add(adder_tree(hi))
        }
    }
}

/// One PE compute slot: apply the SIMD lanes and reduce.
///
/// §Perf: the match is hoisted out of the lane loop so each variant is a
/// tight, auto-vectorizable kernel (the generic `simd_lane`-per-lane
/// formulation kept LLVM from vectorizing the multiply-accumulate).
#[inline]
pub fn pe_slot(x: &[i32], w: &[i32], ty: SimdType) -> i32 {
    debug_assert_eq!(x.len(), w.len());
    match ty {
        SimdType::Xnor => x
            .iter()
            .zip(w)
            .map(|(&a, &b)| (a == b) as i32)
            .fold(0i32, i32::wrapping_add),
        SimdType::BinaryWeights => x
            .iter()
            .zip(w)
            .map(|(&a, &b)| {
                // w in {0,1}: +x / -x without a branch
                let sign = 2 * b - 1;
                a.wrapping_mul(sign)
            })
            .fold(0i32, i32::wrapping_add),
        SimdType::Standard => x
            .iter()
            .zip(w)
            .map(|(&a, &b)| a.wrapping_mul(b))
            .fold(0i32, i32::wrapping_add),
    }
}

/// A whole weight-matrix row as one fold-block pass: bit-identical to the
/// cycle kernel's slot-by-slot evaluation — [`pe_slot`] per `(nf, sf)`
/// slot, `wrapping_add` across slots — because two's-complement wrapping
/// addition is associative and commutative, so regrouping the lane sum is
/// exact, not approximate. The fixed-width blocks break the sequential
/// accumulator dependency so LLVM vectorizes across the former slot
/// boundaries (§Perf: this is the fast kernel's inner loop).
#[inline]
pub fn pe_row(x: &[i32], w: &[i32], ty: SimdType) -> i32 {
    debug_assert_eq!(x.len(), w.len());
    const BLOCK: usize = 64;
    let mut acc = 0i32;
    let mut i = 0;
    while i + BLOCK <= x.len() {
        acc = acc.wrapping_add(pe_slot(&x[i..i + BLOCK], &w[i..i + BLOCK], ty));
        i += BLOCK;
    }
    acc.wrapping_add(pe_slot(&x[i..], &w[i..], ty))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lanes_match_fig4() {
        assert_eq!(simd_lane(1, 1, SimdType::Xnor), 1);
        assert_eq!(simd_lane(0, 1, SimdType::Xnor), 0);
        assert_eq!(simd_lane(0, 0, SimdType::Xnor), 1);
        assert_eq!(simd_lane(5, 1, SimdType::BinaryWeights), 5);
        assert_eq!(simd_lane(5, 0, SimdType::BinaryWeights), -5);
        assert_eq!(simd_lane(-3, 7, SimdType::Standard), -21);
    }

    #[test]
    fn adder_tree_equals_linear_sum() {
        let lanes: Vec<i32> = (-20..30).collect();
        assert_eq!(adder_tree(&lanes), lanes.iter().sum::<i32>());
        assert_eq!(adder_tree(&[]), 0);
        assert_eq!(adder_tree(&[42]), 42);
    }

    #[test]
    fn pe_row_equals_slotwise_accumulation() {
        use crate::util::rng::Pcg32;
        let mut rng = Pcg32::new(42);
        for ty in SimdType::ALL {
            // lengths straddling the block size, including 0 and exact
            // multiples
            for n in [0usize, 1, 7, 63, 64, 65, 128, 200] {
                let bit = matches!(ty, SimdType::Xnor | SimdType::BinaryWeights);
                let x: Vec<i32> = (0..n)
                    .map(|_| {
                        if matches!(ty, SimdType::Xnor) {
                            rng.next_range(2) as i32
                        } else {
                            rng.next_range(15) as i32 - 7
                        }
                    })
                    .collect();
                let w: Vec<i32> = (0..n)
                    .map(|_| {
                        if bit {
                            rng.next_range(2) as i32
                        } else {
                            rng.next_range(15) as i32 - 7
                        }
                    })
                    .collect();
                // slot-wise oracle: arbitrary slot width 8 with remainder
                let mut acc = 0i32;
                let mut i = 0;
                while i < n {
                    let j = (i + 8).min(n);
                    acc = acc.wrapping_add(pe_slot(&x[i..j], &w[i..j], ty));
                    i = j;
                }
                assert_eq!(pe_row(&x, &w, ty), acc, "{ty} n={n}");
            }
        }
    }

    #[test]
    fn pe_slot_matches_reference() {
        use crate::quant::{matvec, Matrix};
        let x = [1, 0, 1, 1];
        let w = Matrix::from_rows(&[vec![1, 1, 0, 1]]).unwrap();
        for ty in SimdType::ALL {
            let expect = matvec(&x, &w, ty).unwrap()[0];
            assert_eq!(pe_slot(&x, w.row(0), ty), expect, "{ty}");
        }
    }
}
