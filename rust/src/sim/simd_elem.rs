//! SIMD elements and the PE reduction (paper Figs. 2 and 4).
//!
//! A SIMD element combines one input lane with one weight lane; the PE
//! reduces the SIMD outputs with a popcount (1-bit) or an adder tree and
//! accumulates across synapse folds.

use crate::cfg::SimdType;
use crate::quant::pack_bits_into;

/// One SIMD element (Fig. 4): (a) XNOR, (b) +/-x mux, (c) multiplier.
#[inline]
pub fn simd_lane(x: i32, w: i32, ty: SimdType) -> i32 {
    match ty {
        SimdType::Xnor => {
            debug_assert!(x == 0 || x == 1, "xnor input lane must be a bit");
            debug_assert!(w == 0 || w == 1, "xnor weight lane must be a bit");
            i32::from(x == w)
        }
        SimdType::BinaryWeights => {
            debug_assert!(w == 0 || w == 1, "binary weight lane must be a bit");
            if w == 1 {
                x
            } else {
                x.wrapping_neg()
            }
        }
        SimdType::Standard => x.wrapping_mul(w),
    }
}

/// The PE's lane reduction as the RTL structures it: a balanced binary
/// adder tree (the shape the delay estimator's logic-depth model prices).
/// Executable documentation of that structure, held equal to the linear
/// sums the datapath kernels use (`pe_slot`/`pe_row`) by the tests —
/// legitimate because wrapping addition is associative and commutative.
///
/// Implemented as an iterative pairwise reduction over a fixed
/// partial-sum stack (one slot per tree level, like a binary carry
/// chain); the former formulation recursed with two slice splits per
/// level, which is needless call-frame traffic for a model that exists
/// to be read and property-tested against.
pub fn adder_tree(lanes: &[i32]) -> i32 {
    // stack[k] holds the root of a complete 2^k-leaf subtree; pushing a
    // leaf merges same-height subtrees exactly like incrementing a binary
    // counter, so usize::BITS slots cover any slice length (and every
    // shift below stays in range).
    let mut stack = [0i32; usize::BITS as usize];
    let mut count: usize = 0;
    for &v in lanes {
        let mut node = v;
        let mut k = 0;
        while count & (1 << k) != 0 {
            node = stack[k].wrapping_add(node);
            k += 1;
        }
        stack[k] = node;
        count += 1;
    }
    // merge the leftover partials, low (rightmost leaves) to high
    let mut acc = 0i32;
    for (k, partial) in stack.iter().enumerate() {
        if count & (1 << k) != 0 {
            acc = partial.wrapping_add(acc);
        }
    }
    acc
}

/// One PE compute slot: apply the SIMD lanes and reduce.
///
/// §Perf: the match is hoisted out of the lane loop so each variant is a
/// tight, auto-vectorizable kernel (the generic `simd_lane`-per-lane
/// formulation kept LLVM from vectorizing the multiply-accumulate).
#[inline]
pub fn pe_slot(x: &[i32], w: &[i32], ty: SimdType) -> i32 {
    debug_assert_eq!(x.len(), w.len());
    match ty {
        SimdType::Xnor => x
            .iter()
            .zip(w)
            .map(|(&a, &b)| (a == b) as i32)
            .fold(0i32, i32::wrapping_add),
        SimdType::BinaryWeights => x
            .iter()
            .zip(w)
            .map(|(&a, &b)| {
                // w in {0,1}: +x / -x without a branch
                let sign = 2 * b - 1;
                a.wrapping_mul(sign)
            })
            .fold(0i32, i32::wrapping_add),
        SimdType::Standard => x
            .iter()
            .zip(w)
            .map(|(&a, &b)| a.wrapping_mul(b))
            .fold(0i32, i32::wrapping_add),
    }
}

/// A whole weight-matrix row as one fold-block pass: bit-identical to the
/// cycle kernel's slot-by-slot evaluation — [`pe_slot`] per `(nf, sf)`
/// slot, `wrapping_add` across slots — because two's-complement wrapping
/// addition is associative and commutative, so regrouping the lane sum is
/// exact, not approximate. The fixed-width blocks break the sequential
/// accumulator dependency so LLVM vectorizes across the former slot
/// boundaries (§Perf: this is the fast kernel's inner loop).
#[inline]
pub fn pe_row(x: &[i32], w: &[i32], ty: SimdType) -> i32 {
    debug_assert_eq!(x.len(), w.len());
    const BLOCK: usize = 64;
    let mut acc = 0i32;
    let mut i = 0;
    while i + BLOCK <= x.len() {
        acc = acc.wrapping_add(pe_slot(&x[i..i + BLOCK], &w[i..i + BLOCK], ty));
        i += BLOCK;
    }
    acc.wrapping_add(pe_slot(&x[i..], &w[i..], ty))
}

/// XNOR row dot product over pre-packed bits: popcount of the word-wise
/// XNOR — exactly the Fig. 4(a) RTL datapath, 64 lanes per operation.
/// `lanes` is the true row length; both slices are `ceil(lanes/64)`
/// zero-padded words, and the tail mask keeps the padding (which would
/// XNOR to all-ones) out of the count.
///
/// Bit-identical to [`pe_row`]`(.., SimdType::Xnor)`: both produce the
/// agreement count modulo 2^32 (the i32 wrapping sum of `+1`s and the u32
/// wrapping popcount accumulate the same residue).
#[inline]
pub fn pe_row_packed_xnor(x: &[u64], w: &[u64], lanes: usize) -> i32 {
    debug_assert_eq!(x.len(), lanes.div_ceil(64));
    debug_assert_eq!(w.len(), x.len());
    let mut agree = 0u32;
    let full = lanes / 64;
    for i in 0..full {
        agree = agree.wrapping_add((!(x[i] ^ w[i])).count_ones());
    }
    let tail = lanes % 64;
    if tail > 0 {
        let mask = (1u64 << tail) - 1;
        agree = agree.wrapping_add((!(x[full] ^ w[full]) & mask).count_ones());
    }
    agree as i32
}

/// Binary-weight row dot product with the weight row as a sign mask:
/// with S = sum of all lanes and S1 = sum of the lanes whose weight bit
/// is set, `sum(w ? x : -x) = 2*S1 - S` — exact in wrapping i32
/// arithmetic because Z/2^32 is a ring, so it is bit-identical to
/// [`pe_row`]`(.., SimdType::BinaryWeights)`. The caller precomputes
/// `total` (= S) once per input vector and amortizes it over every row.
/// `wmask` is zero-padded past the row length, so the bit scan never
/// indexes beyond `x`.
#[inline]
pub fn pe_row_packed_binary(x: &[i32], wmask: &[u64], total: i32) -> i32 {
    debug_assert_eq!(wmask.len(), x.len().div_ceil(64));
    let mut s1 = 0i32;
    for (wi, &word) in wmask.iter().enumerate() {
        let base = wi * 64;
        let mut m = word;
        while m != 0 {
            let b = m.trailing_zeros() as usize;
            s1 = s1.wrapping_add(x[base + b]);
            m &= m - 1;
        }
    }
    s1.wrapping_add(s1).wrapping_sub(total)
}

/// Packing wrapper over the SWAR kernels: evaluate one whole row from
/// unpacked lanes, bit-identical to [`pe_row`] for **every** input —
/// operands outside the packable range ({0,1} inputs/weights for Xnor,
/// {0,1} weights for BinaryWeights) fall back to the flat kernel, exactly
/// as the fast simulation kernel does. The hot path packs once per run
/// and calls [`pe_row_packed_xnor`] / [`pe_row_packed_binary`] directly;
/// this form exists for property tests and one-off callers.
pub fn pe_row_packed(x: &[i32], w: &[i32], ty: SimdType) -> i32 {
    debug_assert_eq!(x.len(), w.len());
    let mut xw = Vec::new();
    let mut ww = Vec::new();
    match ty {
        SimdType::Xnor => {
            if pack_bits_into(x, &mut xw).is_err() || pack_bits_into(w, &mut ww).is_err() {
                return pe_row(x, w, ty);
            }
            pe_row_packed_xnor(&xw, &ww, x.len())
        }
        SimdType::BinaryWeights => {
            if pack_bits_into(w, &mut ww).is_err() {
                return pe_row(x, w, ty);
            }
            let total = x.iter().fold(0i32, |a, &v| a.wrapping_add(v));
            pe_row_packed_binary(x, &ww, total)
        }
        SimdType::Standard => pe_row(x, w, ty),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lanes_match_fig4() {
        assert_eq!(simd_lane(1, 1, SimdType::Xnor), 1);
        assert_eq!(simd_lane(0, 1, SimdType::Xnor), 0);
        assert_eq!(simd_lane(0, 0, SimdType::Xnor), 1);
        assert_eq!(simd_lane(5, 1, SimdType::BinaryWeights), 5);
        assert_eq!(simd_lane(5, 0, SimdType::BinaryWeights), -5);
        assert_eq!(simd_lane(-3, 7, SimdType::Standard), -21);
    }

    #[test]
    fn adder_tree_equals_linear_sum() {
        let lanes: Vec<i32> = (-20..30).collect();
        assert_eq!(adder_tree(&lanes), lanes.iter().sum::<i32>());
        assert_eq!(adder_tree(&[]), 0);
        assert_eq!(adder_tree(&[42]), 42);
    }

    #[test]
    fn pe_row_equals_slotwise_accumulation() {
        use crate::util::rng::Pcg32;
        let mut rng = Pcg32::new(42);
        for ty in SimdType::ALL {
            // lengths straddling the block size, including 0 and exact
            // multiples
            for n in [0usize, 1, 7, 63, 64, 65, 128, 200] {
                let bit = matches!(ty, SimdType::Xnor | SimdType::BinaryWeights);
                let x: Vec<i32> = (0..n)
                    .map(|_| {
                        if matches!(ty, SimdType::Xnor) {
                            rng.next_range(2) as i32
                        } else {
                            rng.next_range(15) as i32 - 7
                        }
                    })
                    .collect();
                let w: Vec<i32> = (0..n)
                    .map(|_| {
                        if bit {
                            rng.next_range(2) as i32
                        } else {
                            rng.next_range(15) as i32 - 7
                        }
                    })
                    .collect();
                // slot-wise oracle: arbitrary slot width 8 with remainder
                let mut acc = 0i32;
                let mut i = 0;
                while i < n {
                    let j = (i + 8).min(n);
                    acc = acc.wrapping_add(pe_slot(&x[i..j], &w[i..j], ty));
                    i = j;
                }
                assert_eq!(pe_row(&x, &w, ty), acc, "{ty} n={n}");
            }
        }
    }

    #[test]
    fn pe_slot_matches_reference() {
        use crate::quant::{matvec, Matrix};
        let x = [1, 0, 1, 1];
        let w = Matrix::from_rows(&[vec![1, 1, 0, 1]]).unwrap();
        for ty in SimdType::ALL {
            let expect = matvec(&x, &w, ty).unwrap()[0];
            assert_eq!(pe_slot(&x, w.row(0), ty), expect, "{ty}");
        }
    }

    /// The packed-datapath identity chain on random inputs:
    /// `popcount_xnor_packed` == `pe_slot(.., Xnor)` == `pe_row_packed`
    /// for bit lanes, and `pe_row_packed` == `pe_row` == `pe_slot` on
    /// every type (including wrapping-heavy BinaryWeights operands).
    #[test]
    fn prop_packed_row_kernels_match_pe_slot() {
        use crate::proptest::{check, Config};
        use crate::quant::popcount_xnor_packed;
        check("packed == slot-wise", Config::cases(150), |g| {
            let n = g.usize_in(0, 300);
            for ty in SimdType::ALL {
                let (xlo, xhi) = match ty {
                    SimdType::Xnor => (0, 1),
                    // wide range so 2*S1 - S actually wraps sometimes
                    _ => (i32::MIN / 2, i32::MAX / 2),
                };
                let x: Vec<i32> = (0..n).map(|_| g.i32_in(xlo, xhi)).collect();
                let w: Vec<i32> = (0..n)
                    .map(|_| match ty {
                        SimdType::Standard => g.i32_in(-8, 7),
                        _ => g.i32_in(0, 1),
                    })
                    .collect();
                let by_slot = pe_slot(&x, &w, ty);
                let by_row = pe_row(&x, &w, ty);
                let by_packed = pe_row_packed(&x, &w, ty);
                if by_slot != by_row || by_row != by_packed {
                    return Err(format!(
                        "{ty} n={n}: slot {by_slot} row {by_row} packed {by_packed}"
                    ));
                }
                if matches!(ty, SimdType::Xnor) {
                    let pc = popcount_xnor_packed(&x, &w).map_err(|e| e.to_string())? as i32;
                    if pc != by_slot {
                        return Err(format!("xnor n={n}: popcount {pc} != slot {by_slot}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn pe_row_packed_falls_back_on_unpackable_operands() {
        // a 2 in an xnor/binary operand cannot be bit-packed; the wrapper
        // must agree with pe_row anyway.
        let x = [1, 0, 2, 1];
        let w = [1, 1, 0, 1];
        assert_eq!(pe_row_packed(&x, &w, SimdType::Xnor), pe_row(&x, &w, SimdType::Xnor));
        let wbad = [1, 0, 2, 1];
        let xi = [5, -3, 7, 11];
        assert_eq!(
            pe_row_packed(&xi, &wbad, SimdType::BinaryWeights),
            pe_row(&xi, &wbad, SimdType::BinaryWeights)
        );
    }

    #[test]
    fn packed_kernels_handle_word_boundaries() {
        // lengths 63/64/65/128/130: full words, exact multiples, tails
        for n in [0usize, 1, 63, 64, 65, 128, 130] {
            let x: Vec<i32> = (0..n).map(|i| ((i * 5) % 3 == 0) as i32).collect();
            let w: Vec<i32> = (0..n).map(|i| ((i * 7) % 2 == 0) as i32).collect();
            assert_eq!(
                pe_row_packed(&x, &w, SimdType::Xnor),
                pe_row(&x, &w, SimdType::Xnor),
                "xnor n={n}"
            );
            let xi: Vec<i32> = (0..n).map(|i| i as i32 * 17 - 40).collect();
            assert_eq!(
                pe_row_packed(&xi, &w, SimdType::BinaryWeights),
                pe_row(&xi, &w, SimdType::BinaryWeights),
                "binary n={n}"
            );
        }
    }
}
