//! The MVU stream unit (paper §5.3, Fig. 6 right): FSM-controlled PE x SIMD
//! datapath with input buffer and output-decoupling FIFO.
//!
//! Cycle semantics (one `step` = one clock cycle):
//!
//!   1. output handshake: if the sink asserts TREADY and the FIFO is not
//!      empty, the front word is transferred this cycle;
//!   2. pipeline advance: the register delay line shifts one stage; a
//!      result leaving the last stage enters the FIFO. If the FIFO cannot
//!      absorb it, the whole datapath stalls this cycle (the FSM drops to
//!      IDLE, Fig. 7) — this is the "compute into the FIFO during
//!      backpressure" behaviour of §5.3.2;
//!   3. the FSM consumes a compute slot: a new input word (WRITE, also
//!      stored to the input buffer) or a buffered word (READ, replay for
//!      the remaining neuron folds). The PE bank evaluates the slot and,
//!      on the last synapse fold, emits a PE-wide output word into the
//!      delay line.
//!
//! The total cycle count from first input to last output equals
//! `SF * NF * OD^2 + PIPELINE_STAGES + 1` with no stalls — asserted
//! against the paper's Table 7 in tests.
//!
//! The default stepped datapath stays on flat i32 lanes deliberately: one
//! `(nf, sf)` slot touches only `SIMD` lanes, too few to amortize
//! bit-packing, and this unit is the semantic reference the packed
//! ideal-flow kernels (DESIGN.md §Packed datapath) are held
//! bit-identical to. The chain fast kernel (`sim::fast::chain`) instead
//! runs this unit with the **row datapath** ([`RowDatapath`]): identical
//! FSM/FIFO/delay-line timing, but the per-slot multiply-accumulate is
//! deferred to the last synapse fold of each neuron fold and evaluated as
//! whole-row dot products over the buffered vector — packed SWAR kernels
//! for the 1-bit SIMD types, flat `pe_row` otherwise. Deferral is exact:
//! every value a row pass produces equals the slot-wise accumulation
//! (wrapping i32 addition is associative), and no timing depends on the
//! accumulator contents.

use std::sync::Arc;

use anyhow::Result;

use crate::cfg::{LayerParams, SimdType, ValidatedParams};
use crate::quant::pack_bits_into;

use super::fifo::Fifo;
use super::fsm::{FsmAction, FsmInputs, FsmState, MvuFsm};
use super::input_buffer::InputBuffer;
use super::pe::Pe;
use super::simd_elem::{pe_row, pe_row_packed_binary, pe_row_packed_xnor};
use super::weight_mem::{PackedWeightMem, WeightMem};
use super::{DEFAULT_FIFO_DEPTH, PIPELINE_STAGES};

/// Result of one clock cycle.
#[derive(Debug, Default)]
pub struct StepOut {
    /// The offered input word was accepted (TVALID && TREADY on the input).
    pub consumed_input: bool,
    /// A word was transferred to the sink this cycle.
    pub emitted: Option<Vec<i32>>,
    /// The datapath stalled this cycle (output FIFO could not absorb).
    pub stalled: bool,
}

/// Per-run statistics.
#[derive(Debug, Clone, Default)]
pub struct StreamStats {
    pub cycles: usize,
    pub idle_cycles: usize,
    pub write_cycles: usize,
    pub read_cycles: usize,
    pub stall_cycles: usize,
    pub slots_consumed: usize,
    pub outputs_emitted: usize,
}

/// Deferred whole-row datapath state (see the module docs). Timing is
/// untouched — only *where* the dot products are evaluated changes, so a
/// row-mode stream is bit-identical to the slot-wise one.
#[derive(Debug)]
struct RowDatapath {
    /// Fold-independent bit packing of the weight matrix
    /// (`Xnor`/`BinaryWeights`); `None` keeps the flat row fallback.
    packed: Option<Arc<PackedWeightMem>>,
    /// Flat copy of the current input vector (rebuilt once per vector
    /// from the input buffer, reused across neuron folds).
    vec: Vec<i32>,
    /// Bit-packed `vec` for the XNOR kernel (valid iff `xnor_packable`).
    xbits: Vec<u64>,
    xnor_packable: bool,
    /// Wrapping lane sum of `vec` (the BinaryWeights `S` term).
    total: i32,
    /// Per-vector state above is valid for the vector in the buffer.
    prepared: bool,
    /// Value replay (DESIGN.md §Batched datapath): `precomputed[i][r]` is
    /// raw row `r` of the `i`-th vector this stream will consume, computed
    /// up front by the blocked batch kernel. When set, `compute_row_word`
    /// emits these values instead of evaluating dot products — sound
    /// because no timing or control signal in the stream unit depends on
    /// accumulator contents, and exact because the blocked kernel is
    /// bit-identical to the per-row evaluation it replaces.
    precomputed: Option<Vec<Vec<i32>>>,
    /// Index into `precomputed` of the *next* vector to begin.
    vec_cursor: usize,
    /// Index into `precomputed` of the vector currently being replayed.
    cur_vec: usize,
}

/// The stream unit.
#[derive(Debug)]
pub struct MvuStream {
    params: LayerParams,
    fsm: MvuFsm,
    buf: InputBuffer,
    pes: Vec<Pe>,
    /// `Some` switches the compute slots to the deferred row datapath.
    row: Option<RowDatapath>,
    /// Register delay line: stage 0 is filled by the PE bank, the last
    /// stage drains into the FIFO.
    delay: Vec<Option<Vec<i32>>>,
    fifo: Fifo<Vec<i32>>,
    /// Fold counters of the *current* input vector.
    cur_sf: usize,
    cur_nf: usize,
    comp_done: bool,
    /// Reusable read-path buffer (avoids a per-cycle allocation on the
    /// READ-state hot path — §Perf).
    scratch: Vec<i32>,
    pub stats: StreamStats,
}

impl MvuStream {
    pub fn new(params: &ValidatedParams) -> Result<MvuStream> {
        Self::with_fifo_depth(params, DEFAULT_FIFO_DEPTH)
    }

    pub fn with_fifo_depth(params: &ValidatedParams, fifo_depth: usize) -> Result<MvuStream> {
        super::fifo::ensure_depth(fifo_depth)?;
        Ok(MvuStream {
            fsm: MvuFsm::new(),
            buf: InputBuffer::new(params.input_buf_depth()),
            pes: (0..params.pe).map(|_| Pe::new()).collect(),
            row: None,
            delay: vec![None; PIPELINE_STAGES],
            fifo: Fifo::new(fifo_depth),
            cur_sf: 0,
            cur_nf: 0,
            comp_done: false,
            scratch: Vec::with_capacity(params.simd),
            stats: StreamStats::default(),
            params: params.params().clone(),
        })
    }

    /// A stream unit running the deferred **row datapath**: identical
    /// cycle behaviour, but compute slots accumulate nothing — each
    /// neuron fold's output word is evaluated as whole-row dot products
    /// over the buffered vector at its last synapse fold, through the
    /// packed SWAR kernels when `packed` is given (`Xnor` /
    /// `BinaryWeights`) and the flat [`pe_row`] otherwise. `packed` must
    /// be a packing of this design point's weight matrix (shape-checked).
    pub fn with_row_datapath(
        params: &ValidatedParams,
        fifo_depth: usize,
        packed: Option<Arc<PackedWeightMem>>,
    ) -> Result<MvuStream> {
        if let Some(pk) = &packed {
            if pk.rows() != params.matrix_rows() || pk.cols() != params.matrix_cols() {
                anyhow::bail!(
                    "shared packed weights {}x{} do not match params {}x{}",
                    pk.rows(),
                    pk.cols(),
                    params.matrix_rows(),
                    params.matrix_cols()
                );
            }
        }
        let mut s = Self::with_fifo_depth(params, fifo_depth)?;
        s.row = Some(RowDatapath {
            packed,
            vec: Vec::with_capacity(params.matrix_cols()),
            xbits: Vec::new(),
            xnor_packable: false,
            total: 0,
            prepared: false,
            precomputed: None,
            vec_cursor: 0,
            cur_vec: 0,
        });
        Ok(s)
    }

    /// Hand a row-datapath stream the precomputed raw row outputs of every
    /// vector it will consume, in consumption order (value replay — see
    /// [`RowDatapath::precomputed`]). `outputs[i][r]` must equal the raw
    /// dot product of vector `i` with weight row `r`; the chain fast
    /// kernel computes them with the blocked batch kernel
    /// (`eval_rows_batched`) so each stage's weight matrix is walked once
    /// per batch instead of once per vector. Returns a structured error
    /// when called on a slot-wise stream or when any preloaded vector
    /// does not carry one output per weight row.
    pub fn preload_row_outputs(&mut self, outputs: Vec<Vec<i32>>) -> Result<()> {
        let rows = self.params.matrix_rows();
        if let Some(bad) = outputs.iter().position(|o| o.len() != rows) {
            anyhow::bail!(
                "preload_row_outputs: outputs[{bad}] has {} rows, expected {rows}",
                outputs[bad].len()
            );
        }
        let Some(row) = self.row.as_mut() else {
            anyhow::bail!("preload_row_outputs requires the row datapath (slot-wise stream)");
        };
        row.precomputed = Some(outputs);
        row.vec_cursor = 0;
        Ok(())
    }

    pub fn params(&self) -> &LayerParams {
        &self.params
    }

    pub fn fsm_state(&self) -> FsmState {
        self.fsm.state
    }

    pub fn fifo_max_occupancy(&self) -> usize {
        self.fifo.max_occupancy
    }

    /// Anything still in flight?
    pub fn drained(&self) -> bool {
        self.fifo.is_empty() && self.delay.iter().all(Option::is_none)
    }

    /// Buffered folds of the current vector remain to be replayed
    /// (INP_BUF_FULL && !COMP_DONE, Fig. 7).
    pub fn has_pending_folds(&self) -> bool {
        self.buf.full() && !self.comp_done
    }

    /// A result sits in the last delay stage while the FIFO is full: unless
    /// the sink pops a word this cycle, the whole datapath freezes
    /// (§5.3.2). The fast kernel jumps over such intervals.
    pub fn output_blocked(&self) -> bool {
        self.delay[PIPELINE_STAGES - 1].is_some() && self.fifo.is_full()
    }

    /// Nothing in flight and nothing to do without new input: a [`step`]
    /// with no offered word is provably a no-op apart from the cycle
    /// counters. The fast kernel and [`MvuChain`](super::MvuChain) advance
    /// the clock over such cycles without dispatching the FSM.
    ///
    /// [`step`]: Self::step
    pub fn quiescent_without_input(&self) -> bool {
        self.fsm.state == FsmState::Idle
            && !self.has_pending_folds()
            && self.fifo.is_empty()
            && self.delay.iter().all(Option::is_none)
    }

    /// Output words are parked in the FIFO with the datapath otherwise
    /// empty and the FSM idle: a [`step`](Self::step) with no offered
    /// word and an unready sink is then provably a no-op apart from the
    /// cycle counters (no pop, no delay shift, FSM stays IDLE) — the same
    /// counter increments as a quiescent cycle. The chain fast kernel
    /// skips such intervals with [`skip_idle_cycles`](Self::skip_idle_cycles).
    pub fn parked_on_output(&self) -> bool {
        self.fsm.state == FsmState::Idle
            && !self.has_pending_folds()
            && !self.fifo.is_empty()
            && self.delay.iter().all(Option::is_none)
    }

    /// Advance the clock over `n` cycles in which the datapath is frozen on
    /// output backpressure ([`output_blocked`](Self::output_blocked) with
    /// the sink never ready): bit-identical to `n` calls of
    /// [`step`](Self::step) each returning `stalled == true`, in closed
    /// form. The first blocked cycle drops the FSM to IDLE (Fig. 7) and it
    /// stays there, so forcing IDLE once covers the whole interval.
    pub fn skip_blocked_cycles(&mut self, n: usize) {
        debug_assert!(self.output_blocked(), "skip_blocked_cycles on a live datapath");
        self.fsm.state = FsmState::Idle;
        self.stats.cycles += n;
        self.stats.stall_cycles += n;
        self.stats.idle_cycles += n;
    }

    /// Advance the clock over `n` quiescent cycles
    /// ([`quiescent_without_input`](Self::quiescent_without_input) with no
    /// input offered): bit-identical to `n` idle [`step`](Self::step)s.
    /// Equally valid for [`parked_on_output`](Self::parked_on_output)
    /// intervals with an unready sink — those steps increment exactly the
    /// same counters.
    pub fn skip_idle_cycles(&mut self, n: usize) {
        debug_assert!(
            self.quiescent_without_input() || self.parked_on_output(),
            "skip_idle_cycles with work pending"
        );
        self.stats.cycles += n;
        self.stats.idle_cycles += n;
    }

    /// One clock cycle.
    pub fn step(&mut self, offered: Option<&[i32]>, wmem: &WeightMem, out_ready: bool) -> StepOut {
        self.stats.cycles += 1;
        let mut out = StepOut::default();

        // 1. output handshake
        if out_ready {
            if let Some(word) = self.fifo.pop() {
                self.stats.outputs_emitted += 1;
                out.emitted = Some(word);
            }
        }

        // 2. pipeline advance (or stall)
        let last = PIPELINE_STAGES - 1;
        let blocked = self.delay[last].is_some() && self.fifo.is_full();
        if blocked {
            // datapath frozen: registers hold, FSM sees a stall.
            out.stalled = true;
            self.stats.stall_cycles += 1;
            let _ = self.fsm.step(FsmInputs {
                in_valid: offered.is_some(),
                inp_buf_full: self.buf.full(),
                comp_done: self.comp_done,
                stalled: true,
            });
            self.stats.idle_cycles += 1;
            return out;
        }
        if let Some(word) = self.delay[last].take() {
            self.fifo.push(word);
        }
        for i in (1..=last).rev() {
            self.delay[i] = self.delay[i - 1].take();
        }

        // 3. FSM + compute slot
        let action = self.fsm.step(FsmInputs {
            in_valid: offered.is_some(),
            inp_buf_full: self.buf.full(),
            comp_done: self.comp_done,
            stalled: false,
        });
        match action {
            FsmAction::Nothing => {
                self.stats.idle_cycles += 1;
            }
            FsmAction::ConsumeInput => {
                self.stats.write_cycles += 1;
                // lint: allow(panic-path, FSM emits ConsumeInput only when in_valid was asserted)
                let word = offered.expect("FSM consumed without an offer");
                if self.comp_done {
                    // previous vector fully processed: restart for the next
                    self.buf.restart();
                    self.cur_sf = 0;
                    self.cur_nf = 0;
                    self.comp_done = false;
                    if let Some(row) = &mut self.row {
                        row.prepared = false;
                    }
                }
                self.buf.write(word);
                self.compute_slot(word, wmem);
                out.consumed_input = true;
            }
            FsmAction::ReadBuffer => {
                self.stats.read_cycles += 1;
                // move the scratch out to satisfy the borrow checker while
                // keeping its capacity (no allocation in steady state)
                let mut scratch = std::mem::take(&mut self.scratch);
                scratch.clear();
                scratch.extend_from_slice(self.buf.read_next());
                self.compute_slot(&scratch, wmem);
                self.scratch = scratch;
            }
        }
        out
    }

    /// Evaluate one (nf, sf) compute slot on the PE bank.
    fn compute_slot(&mut self, x: &[i32], wmem: &WeightMem) {
        debug_assert_eq!(x.len(), self.params.simd, "input word width != SIMD");
        let sf_total = self.params.synapse_fold();
        let nf_total = self.params.neuron_fold();
        debug_assert!(self.cur_nf < nf_total, "slot beyond comp_done");
        let first = self.cur_sf == 0;
        let last = self.cur_sf == sf_total - 1;
        if self.row.is_some() {
            if last {
                self.compute_row_word(wmem, sf_total);
            }
        } else {
            let addr = self.cur_nf * sf_total + self.cur_sf;
            let ty = self.params.simd_type;
            let mut result: Option<Vec<i32>> = last.then(|| Vec::with_capacity(self.pes.len()));
            for (p, pe) in self.pes.iter_mut().enumerate() {
                let w = wmem.read(p, addr);
                let r = pe.slot(x, w, ty, first, last);
                if let (Some(out), Some(v)) = (&mut result, r) {
                    out.push(v);
                }
            }
            if let Some(word) = result {
                debug_assert!(self.delay[0].is_none(), "delay stage collision");
                self.delay[0] = Some(word);
            }
        }
        self.stats.slots_consumed += 1;
        self.cur_sf += 1;
        if self.cur_sf == sf_total {
            self.cur_sf = 0;
            self.cur_nf += 1;
            if self.cur_nf == nf_total {
                self.comp_done = true;
            }
        }
    }

    /// Row-datapath evaluation of neuron fold `cur_nf`'s output word: one
    /// whole-row dot product per PE over the buffered vector. Called only
    /// at the last synapse fold, where the input buffer provably holds
    /// the complete vector (nf 0 finishes on the write of word SF-1; the
    /// replay folds run from a full buffer). Bit-identical to the
    /// slot-wise accumulation by associativity of wrapping addition and
    /// the SWAR identities (DESIGN.md §Packed datapath); unpackable
    /// operands fall back to the flat [`pe_row`].
    fn compute_row_word(&mut self, wmem: &WeightMem, sf_total: usize) {
        // lint: allow(panic-path, compute_slot dispatches here only when self.row is Some)
        let mut row = self.row.take().expect("row datapath state");
        if !row.prepared {
            if row.precomputed.is_some() {
                // value replay: the next vector's rows are already
                // computed; nothing to copy or pack.
                row.cur_vec = row.vec_cursor;
                row.vec_cursor += 1;
            } else {
                row.vec.clear();
                self.buf.copy_vector_into(&mut row.vec);
                match self.params.simd_type {
                    SimdType::Xnor => {
                        row.xnor_packable = row.packed.is_some()
                            && pack_bits_into(&row.vec, &mut row.xbits).is_ok();
                    }
                    SimdType::BinaryWeights => {
                        row.total = row.vec.iter().fold(0i32, |a, &v| a.wrapping_add(v));
                    }
                    SimdType::Standard => {}
                }
            }
            row.prepared = true;
        }
        let pe_n = self.params.pe;
        let cols = self.params.matrix_cols();
        let ty = self.params.simd_type;
        let mut word = Vec::with_capacity(pe_n);
        for p in 0..pe_n {
            let r = self.cur_nf * pe_n + p;
            let v = if let Some(pre) = &row.precomputed {
                pre[row.cur_vec][r]
            } else {
                match (ty, &row.packed) {
                    (SimdType::Xnor, Some(pk)) if row.xnor_packable => {
                        pe_row_packed_xnor(&row.xbits, pk.row_words(r), cols)
                    }
                    (SimdType::BinaryWeights, Some(pk)) => {
                        pe_row_packed_binary(&row.vec, pk.row_words(r), row.total)
                    }
                    _ => pe_row(&row.vec, wmem.read_row(p, self.cur_nf, sf_total), ty),
                }
            };
            word.push(v);
        }
        debug_assert!(self.delay[0].is_none(), "delay stage collision");
        self.delay[0] = Some(word);
        self.row = Some(row);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::Matrix;

    fn setup(pe: usize, simd: usize) -> (crate::cfg::ValidatedParams, WeightMem) {
        let p = crate::cfg::DesignPoint::fc("t")
            .in_features(8)
            .out_features(4)
            .pe(pe)
            .simd(simd)
            .build()
            .unwrap();
        let data: Vec<i32> = (0..32).map(|i| (i % 7) - 3).collect();
        let w = Matrix::new(4, 8, data).unwrap();
        let wm = WeightMem::from_matrix(&p, &w).unwrap();
        (p, wm)
    }

    #[test]
    fn single_vector_full_fold() {
        // PE=2 (NF=2), SIMD=4 (SF=2): 4 slots, 2 output words.
        let (p, wm) = setup(2, 4);
        let mut s = MvuStream::new(&p).unwrap();
        let x: Vec<i32> = (0..8).collect();
        let words = [x[0..4].to_vec(), x[4..8].to_vec()];
        let mut outs = Vec::new();
        let mut wi = 0;
        for _cycle in 0..40 {
            let offered = (wi < 2).then(|| words[wi].clone());
            let r = s.step(offered.as_deref(), &wm, true);
            if r.consumed_input {
                wi += 1;
            }
            if let Some(o) = r.emitted {
                outs.push(o);
            }
        }
        // flatten channel order nf-major
        let got: Vec<i32> = outs.concat();
        let expect = crate::quant::matvec_standard(
            &x,
            &Matrix::new(
                4,
                8,
                (0..32).map(|i| (i % 7) - 3).collect(),
            )
            .unwrap(),
        )
        .unwrap();
        // output word nf contains rows nf*PE..nf*PE+PE -> already row order
        assert_eq!(got, expect);
    }

    #[test]
    fn cycle_count_matches_formula() {
        let (p, wm) = setup(2, 4);
        let mut s = MvuStream::new(&p).unwrap();
        let x: Vec<i32> = (0..8).collect();
        let words = [x[0..4].to_vec(), x[4..8].to_vec()];
        let mut wi = 0;
        let mut last_out_cycle = 0;
        let mut outs = 0;
        for cycle in 0..40 {
            let offered = (wi < 2).then(|| words[wi].clone());
            let r = s.step(offered.as_deref(), &wm, true);
            if r.consumed_input {
                wi += 1;
            }
            if r.emitted.is_some() {
                outs += 1;
                last_out_cycle = cycle;
            }
        }
        assert_eq!(outs, 2);
        // SF*NF = 4 slots + PIPELINE_STAGES + 1
        assert_eq!(last_out_cycle + 1, p.analytic_cycles(PIPELINE_STAGES));
    }

    #[test]
    fn skip_blocked_cycles_matches_stepped_blocked_cycles() {
        // drive two identical machines into an output-blocked jam (never-
        // ready sink), then advance one tick-by-tick and the other with
        // the closed form the fast kernel uses.
        let (p, wm) = setup(2, 4);
        let mut a = MvuStream::with_fifo_depth(&p, 1).unwrap();
        let mut b = MvuStream::with_fifo_depth(&p, 1).unwrap();
        let x: Vec<i32> = (0..8).collect();
        let words = [x[0..4].to_vec(), x[4..8].to_vec()];
        let mut wi = 0;
        for _ in 0..40 {
            let offered = (wi < 2).then(|| words[wi].clone());
            let ra = a.step(offered.as_deref(), &wm, false);
            let rb = b.step(offered.as_deref(), &wm, false);
            assert_eq!(ra.consumed_input, rb.consumed_input);
            if ra.consumed_input {
                wi += 1;
            }
        }
        assert!(a.output_blocked() && b.output_blocked());
        for _ in 0..7 {
            let r = a.step(None, &wm, false);
            assert!(r.stalled);
        }
        b.skip_blocked_cycles(7);
        assert_eq!(a.fsm_state(), b.fsm_state());
        assert_eq!(a.stats.cycles, b.stats.cycles);
        assert_eq!(a.stats.stall_cycles, b.stats.stall_cycles);
        assert_eq!(a.stats.idle_cycles, b.stats.idle_cycles);
    }

    #[test]
    fn skip_idle_cycles_matches_stepped_idle_cycles() {
        let (p, wm) = setup(2, 4);
        let mut a = MvuStream::new(&p).unwrap();
        let mut b = MvuStream::new(&p).unwrap();
        assert!(a.quiescent_without_input());
        for _ in 0..5 {
            a.step(None, &wm, true);
        }
        b.skip_idle_cycles(5);
        assert_eq!(a.fsm_state(), b.fsm_state());
        assert_eq!(a.stats.cycles, b.stats.cycles);
        assert_eq!(a.stats.idle_cycles, b.stats.idle_cycles);
        assert!(b.quiescent_without_input());
    }

    #[test]
    fn zero_fifo_depth_is_an_error() {
        let (p, _) = setup(2, 4);
        assert!(MvuStream::with_fifo_depth(&p, 0).is_err());
    }

    /// The row datapath must be cycle-for-cycle and value-for-value
    /// identical to the slot-wise one, including under backpressure and
    /// across multiple vectors (the chain fast kernel's core lemma).
    #[test]
    fn row_datapath_is_bit_identical_to_slotwise() {
        use crate::cfg::SimdType;
        for ty in SimdType::ALL {
            let p = crate::cfg::DesignPoint::fc("row")
                .in_features(8)
                .out_features(4)
                .pe(2)
                .simd(4)
                .paper_precision(ty)
                .build()
                .unwrap();
            let mut rng = crate::util::rng::Pcg32::new(31);
            let bit = !matches!(ty, SimdType::Standard);
            let data: Vec<i32> = (0..32)
                .map(|_| {
                    if bit {
                        rng.next_range(2) as i32
                    } else {
                        rng.next_range(8) as i32 - 4
                    }
                })
                .collect();
            let w = Matrix::new(4, 8, data).unwrap();
            let wm = WeightMem::from_matrix(&p, &w).unwrap();
            let packed = PackedWeightMem::from_matrix(&w).ok().map(Arc::new);
            let mut slot = MvuStream::with_fifo_depth(&p, 2).unwrap();
            let mut row = MvuStream::with_row_datapath(&p, 2, packed).unwrap();
            let words: Vec<Vec<i32>> = (0..3)
                .flat_map(|_| {
                    let v: Vec<i32> = (0..8)
                        .map(|_| {
                            if matches!(ty, SimdType::Xnor) {
                                rng.next_range(2) as i32
                            } else {
                                rng.next_range(8) as i32 - 4
                            }
                        })
                        .collect();
                    vec![v[0..4].to_vec(), v[4..8].to_vec()]
                })
                .collect();
            let mut wi = 0;
            for cycle in 0..120 {
                let offered = (wi < words.len()).then(|| words[wi].clone());
                let ready = cycle % 3 != 0; // periodic backpressure
                let a = slot.step(offered.as_deref(), &wm, ready);
                let b = row.step(offered.as_deref(), &wm, ready);
                assert_eq!(a.consumed_input, b.consumed_input, "{ty} cycle {cycle}");
                assert_eq!(a.stalled, b.stalled, "{ty} cycle {cycle}");
                assert_eq!(a.emitted, b.emitted, "{ty} cycle {cycle}");
                if a.consumed_input {
                    wi += 1;
                }
            }
            assert_eq!(slot.stats.cycles, row.stats.cycles, "{ty}");
            assert_eq!(slot.stats.slots_consumed, row.stats.slots_consumed, "{ty}");
            assert_eq!(slot.stats.stall_cycles, row.stats.stall_cycles, "{ty}");
            assert!(slot.drained() && row.drained(), "{ty}");
        }
    }

    /// Value replay ([`MvuStream::preload_row_outputs`]) must be
    /// cycle-for-cycle and value-for-value identical to the row datapath
    /// computing its own dot products — including under backpressure and
    /// across the multi-vector boundary where `prepared` resets.
    #[test]
    fn preloaded_row_outputs_are_bit_identical_to_computed() {
        use crate::cfg::SimdType;
        for ty in SimdType::ALL {
            let p = crate::cfg::DesignPoint::fc("pre")
                .in_features(8)
                .out_features(4)
                .pe(2)
                .simd(4)
                .paper_precision(ty)
                .build()
                .unwrap();
            let mut rng = crate::util::rng::Pcg32::new(47);
            let bit = !matches!(ty, SimdType::Standard);
            let data: Vec<i32> = (0..32)
                .map(|_| {
                    if bit {
                        rng.next_range(2) as i32
                    } else {
                        rng.next_range(8) as i32 - 4
                    }
                })
                .collect();
            let w = Matrix::new(4, 8, data).unwrap();
            let wm = WeightMem::from_matrix(&p, &w).unwrap();
            let packed = PackedWeightMem::from_matrix(&w).ok().map(Arc::new);
            let vecs: Vec<Vec<i32>> = (0..3)
                .map(|_| {
                    (0..8)
                        .map(|_| {
                            if matches!(ty, SimdType::Xnor) {
                                rng.next_range(2) as i32
                            } else {
                                rng.next_range(8) as i32 - 4
                            }
                        })
                        .collect()
                })
                .collect();
            let raw: Vec<Vec<i32>> =
                vecs.iter().map(|v| crate::quant::matvec(v, &w, ty).unwrap()).collect();
            let mut live = MvuStream::with_row_datapath(&p, 2, packed.clone()).unwrap();
            let mut replay = MvuStream::with_row_datapath(&p, 2, packed).unwrap();
            replay.preload_row_outputs(raw).unwrap();
            let words: Vec<Vec<i32>> = vecs
                .iter()
                .flat_map(|v| vec![v[0..4].to_vec(), v[4..8].to_vec()])
                .collect();
            let mut wi = 0;
            for cycle in 0..120 {
                let offered = (wi < words.len()).then(|| words[wi].clone());
                let ready = cycle % 3 != 0; // periodic backpressure
                let a = live.step(offered.as_deref(), &wm, ready);
                let b = replay.step(offered.as_deref(), &wm, ready);
                assert_eq!(a.consumed_input, b.consumed_input, "{ty} cycle {cycle}");
                assert_eq!(a.stalled, b.stalled, "{ty} cycle {cycle}");
                assert_eq!(a.emitted, b.emitted, "{ty} cycle {cycle}");
                if a.consumed_input {
                    wi += 1;
                }
            }
            assert_eq!(live.stats.cycles, replay.stats.cycles, "{ty}");
            assert_eq!(live.stats.slots_consumed, replay.stats.slots_consumed, "{ty}");
            assert!(live.drained() && replay.drained(), "{ty}");
        }
    }

    #[test]
    fn parked_on_output_matches_skip_semantics() {
        // run a vector to completion with a never-ready sink and depth
        // large enough that the datapath never blocks: the words park in
        // the FIFO, and stepped vs skipped idle cycles agree.
        let (p, wm) = setup(2, 4);
        let mut a = MvuStream::with_fifo_depth(&p, 4).unwrap();
        let mut b = MvuStream::with_fifo_depth(&p, 4).unwrap();
        let x: Vec<i32> = (0..8).collect();
        let words = [x[0..4].to_vec(), x[4..8].to_vec()];
        let mut wi = 0;
        for _ in 0..20 {
            let offered = (wi < 2).then(|| words[wi].clone());
            let ra = a.step(offered.as_deref(), &wm, false);
            let rb = b.step(offered.as_deref(), &wm, false);
            assert_eq!(ra.consumed_input, rb.consumed_input);
            if ra.consumed_input {
                wi += 1;
            }
        }
        assert!(a.parked_on_output() && b.parked_on_output());
        assert!(!a.output_blocked());
        for _ in 0..6 {
            let r = a.step(None, &wm, false);
            assert!(!r.stalled && r.emitted.is_none());
        }
        b.skip_idle_cycles(6);
        assert_eq!(a.fsm_state(), b.fsm_state());
        assert_eq!(a.stats.cycles, b.stats.cycles);
        assert_eq!(a.stats.idle_cycles, b.stats.idle_cycles);
        assert_eq!(a.stats.stall_cycles, b.stats.stall_cycles);
    }

    #[test]
    fn backpressure_does_not_lose_data() {
        let (p, wm) = setup(2, 4);
        let mut s = MvuStream::new(&p).unwrap();
        let x: Vec<i32> = (0..8).collect();
        let words = [x[0..4].to_vec(), x[4..8].to_vec()];
        let mut wi = 0;
        let mut outs = Vec::new();
        for cycle in 0..200 {
            let offered = (wi < 2).then(|| words[wi].clone());
            // sink only ready every 7th cycle
            let ready = cycle % 7 == 0;
            let r = s.step(offered.as_deref(), &wm, ready);
            if r.consumed_input {
                wi += 1;
            }
            if let Some(o) = r.emitted {
                outs.push(o);
            }
        }
        assert_eq!(outs.len(), 2);
        let expect = crate::quant::matvec_standard(
            &x,
            &Matrix::new(4, 8, (0..32).map(|i| (i % 7) - 3).collect()).unwrap(),
        )
        .unwrap();
        assert_eq!(outs.concat(), expect);
        assert!(s.drained());
    }
}
