//! Sliding-window unit (paper §4.1, Fig. 1): expands the input feature
//! map into the stream of K^2*IC-long vectors consumed by the MVU, one
//! per output pixel — im2col on the fly.
//!
//! Ordering contract (shared with `kernels/swu.py::swu_indices` and
//! `ref.im2col`): pixels in (oy, ox) raster order, vector elements in
//! (ky, kx, ic) order.

use anyhow::{bail, Result};

/// The SWU for a fixed geometry.
#[derive(Debug, Clone)]
pub struct SlidingWindowUnit {
    pub h: usize,
    pub w: usize,
    pub ic: usize,
    pub kd: usize,
    pub stride: usize,
}

impl SlidingWindowUnit {
    pub fn new(
        h: usize,
        w: usize,
        ic: usize,
        kd: usize,
        stride: usize,
    ) -> Result<SlidingWindowUnit> {
        if kd == 0 || stride == 0 {
            bail!("kernel dim and stride must be positive");
        }
        if kd > h || kd > w {
            bail!("kernel {kd} larger than image {h}x{w}");
        }
        Ok(SlidingWindowUnit { h, w, ic, kd, stride })
    }

    pub fn out_h(&self) -> usize {
        (self.h - self.kd) / self.stride + 1
    }

    pub fn out_w(&self) -> usize {
        (self.w - self.kd) / self.stride + 1
    }

    pub fn out_pixels(&self) -> usize {
        self.out_h() * self.out_w()
    }

    pub fn vector_len(&self) -> usize {
        self.kd * self.kd * self.ic
    }

    /// Expand one image (flat HWC layout, len H*W*IC) into the stream of
    /// per-pixel vectors.
    pub fn expand(&self, img: &[i32]) -> Result<Vec<Vec<i32>>> {
        if img.len() != self.h * self.w * self.ic {
            bail!("image length {} != {}x{}x{}", img.len(), self.h, self.w, self.ic);
        }
        let mut out = Vec::with_capacity(self.out_pixels());
        for oy in 0..self.out_h() {
            for ox in 0..self.out_w() {
                let mut v = Vec::with_capacity(self.vector_len());
                for ky in 0..self.kd {
                    for kx in 0..self.kd {
                        let y = oy * self.stride + ky;
                        let x = ox * self.stride + kx;
                        let base = (y * self.w + x) * self.ic;
                        v.extend_from_slice(&img[base..base + self.ic]);
                    }
                }
                out.push(v);
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometry() {
        let s = SlidingWindowUnit::new(8, 8, 3, 3, 1).unwrap();
        assert_eq!(s.out_h(), 6);
        assert_eq!(s.out_pixels(), 36);
        assert_eq!(s.vector_len(), 27);
    }

    #[test]
    fn expand_2x2_kernel_on_3x3_image() {
        // 3x3 image, 1 channel, values = linear index
        let img: Vec<i32> = (0..9).collect();
        let s = SlidingWindowUnit::new(3, 3, 1, 2, 1).unwrap();
        let v = s.expand(&img).unwrap();
        assert_eq!(v.len(), 4);
        assert_eq!(v[0], vec![0, 1, 3, 4]); // top-left window
        assert_eq!(v[1], vec![1, 2, 4, 5]);
        assert_eq!(v[2], vec![3, 4, 6, 7]);
        assert_eq!(v[3], vec![4, 5, 7, 8]);
    }

    #[test]
    fn channel_ordering_is_kykxic() {
        // 2x2 image, 2 channels
        let img = vec![10, 11, 20, 21, 30, 31, 40, 41]; // (y,x,c) flat
        let s = SlidingWindowUnit::new(2, 2, 2, 2, 1).unwrap();
        let v = s.expand(&img).unwrap();
        assert_eq!(v.len(), 1);
        assert_eq!(v[0], img); // single window covers all, same order
    }

    #[test]
    fn stride_2() {
        let img: Vec<i32> = (0..16).collect();
        let s = SlidingWindowUnit::new(4, 4, 1, 2, 2).unwrap();
        let v = s.expand(&img).unwrap();
        assert_eq!(v.len(), 4);
        assert_eq!(v[0], vec![0, 1, 4, 5]);
        assert_eq!(v[3], vec![10, 11, 14, 15]);
    }

    #[test]
    fn rejects_bad_sizes() {
        assert!(SlidingWindowUnit::new(2, 2, 1, 3, 1).is_err());
        let s = SlidingWindowUnit::new(3, 3, 1, 2, 1).unwrap();
        assert!(s.expand(&[0; 5]).is_err());
    }
}
