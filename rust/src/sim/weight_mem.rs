//! Per-PE weight memories (paper §5.1, Eq. 2).
//!
//! Each PE owns a memory of depth `D_mem = K_d^2 * I_c * O_c / (SIMD * PE)`
//! holding `SIMD * B_w`-bit words; word `nf * SF + sf` carries the SIMD
//! weights of row `nf * PE + pe`, columns `sf * SIMD ..`. Contents are
//! "burned in" offline (here: loaded from the weight matrix at
//! construction), matching both the RTL and the HLO-constant artifacts.

use anyhow::{bail, Result};

use crate::cfg::ValidatedParams;
use crate::quant::{Matrix, PackedMatrix};
use crate::util::rng::Pcg32;

/// All PE weight memories of one MVU.
///
/// Storage is a single flat buffer indexed `(pe * depth + addr) * simd`
/// (§Perf: the nested-Vec layout dominated both construction time and
/// read-path cache behaviour on the simulator hot loop).
#[derive(Debug, Clone)]
pub struct WeightMem {
    pub pe: usize,
    pub simd: usize,
    pub depth: usize,
    mem: Vec<i32>,
}

impl WeightMem {
    /// Partition the (rows x cols) weight matrix across PE memories
    /// according to the paper's layout: PE `p` serves rows `nf * PE + p`.
    /// Takes a [`ValidatedParams`] like every sim constructor, so an
    /// illegal fold cannot reach the partition arithmetic.
    pub fn from_matrix(params: &ValidatedParams, w: &Matrix) -> Result<WeightMem> {
        if w.rows != params.matrix_rows() || w.cols != params.matrix_cols() {
            bail!(
                "weight matrix {}x{} does not match params {}x{}",
                w.rows,
                w.cols,
                params.matrix_rows(),
                params.matrix_cols()
            );
        }
        let (pe, simd) = (params.pe, params.simd);
        let sf = params.synapse_fold();
        let nf = params.neuron_fold();
        let depth = params.weight_mem_depth();
        debug_assert_eq!(depth, sf * nf);
        let mut mem = vec![0i32; pe * depth * simd];
        for p in 0..pe {
            for n in 0..nf {
                let row = n * pe + p;
                for s in 0..sf {
                    let addr = n * sf + s;
                    let base = (p * depth + addr) * simd;
                    mem[base..base + simd]
                        .copy_from_slice(&w.row(row)[s * simd..(s + 1) * simd]);
                }
            }
        }
        Ok(WeightMem { pe, simd, depth, mem })
    }

    /// Synchronous read: word `addr` of PE `p`'s memory.
    #[inline]
    pub fn read(&self, p: usize, addr: usize) -> &[i32] {
        let base = (p * self.depth + addr) * self.simd;
        &self.mem[base..base + self.simd]
    }

    /// All `sf_total` words of neuron fold `nf` as one contiguous slice —
    /// addresses `nf*SF .. (nf+1)*SF` are adjacent in the flat layout, and
    /// their concatenation is exactly row `nf*PE + p` of the weight matrix
    /// in column order (asserted by `fold_row_is_the_matrix_row`). This
    /// layout fact is what lets the fast kernel's ideal path read rows
    /// straight off the source [`Matrix`] (`sim::fast::run_ideal` uses
    /// `Matrix::row`) while staying word-for-word faithful to what the
    /// per-cycle kernel streams out of these memories.
    #[inline]
    pub fn read_row(&self, p: usize, nf: usize, sf_total: usize) -> &[i32] {
        let base = (p * self.depth + nf * sf_total) * self.simd;
        &self.mem[base..base + sf_total * self.simd]
    }

    /// Total weight bits stored (for the BRAM estimator).
    pub fn total_bits(&self, weight_bits: u32) -> usize {
        self.pe * self.depth * self.simd * weight_bits as usize
    }

    /// Fault-injection hook (device fault model): toggle `flips` seeded
    /// bits across the stored lanes. Each flip picks a lane and a bit
    /// position below `weight_bits`, toggles it in the lane's
    /// `weight_bits`-wide encoding, and (for `signed` layers)
    /// sign-extends back, so a corrupted lane stays inside the domain
    /// the datapath reads. Inert unless called — no simulation result
    /// changes for a run that never injects corruption. Returns the
    /// flips applied (0 for an empty memory).
    pub fn flip_bits(&mut self, seed: u64, flips: usize, weight_bits: u32, signed: bool) -> usize {
        if self.mem.is_empty() || weight_bits == 0 {
            return 0;
        }
        let mut rng = Pcg32::with_stream(seed, 0x77);
        let bits = weight_bits.min(31);
        let width_mask = (1u32 << bits) - 1;
        for _ in 0..flips {
            let lane = (rng.next_u64() % self.mem.len() as u64) as usize;
            let bit = rng.next_range(bits);
            let raw = ((self.mem[lane] as u32) & width_mask) ^ (1 << bit);
            let sign = 1u32 << (bits - 1);
            self.mem[lane] = if signed && raw & sign != 0 {
                (raw | !width_mask) as i32
            } else {
                raw as i32
            };
        }
        flips
    }

    /// Lanes where this memory differs from `other` (same geometry
    /// assumed; used to audit injected corruption).
    pub fn diff_lanes(&self, other: &WeightMem) -> usize {
        debug_assert_eq!(self.mem.len(), other.mem.len());
        self.mem.iter().zip(&other.mem).filter(|(a, b)| a != b).count()
    }

    /// Restore every lane from `golden` (the quarantine-exit scrub).
    pub fn scrub_from(&mut self, golden: &WeightMem) -> Result<()> {
        if (self.pe, self.simd, self.depth) != (golden.pe, golden.simd, golden.depth) {
            bail!("scrub: weight memory shapes differ");
        }
        self.mem.copy_from_slice(&golden.mem);
        Ok(())
    }
}

/// Bit-packed weight memories for the 1-bit datapaths
/// (`SimdType::{Xnor, BinaryWeights}`; Standard keeps the flat i32
/// [`WeightMem`]).
///
/// Storage is the weight matrix packed one bit per lane
/// ([`PackedMatrix`]: row-major, each row word-aligned, LSB-first), which
/// is exactly the concatenation of PE `p`'s `SIMD * B_w`-bit memory words
/// `nf*SF .. (nf+1)*SF` for row `nf*PE + p` — the packed analogue of
/// [`WeightMem::read_row`]'s contiguity guarantee, asserted by
/// `packed_words_match_flat_memory`.
///
/// Deliberately **fold-independent**: PE/SIMD only choose how the row
/// bits are *framed* into memory words, not where they live, so one
/// packing serves every legal (PE, SIMD) folding of the same matrix.
/// That is what lets the explore engine share a single
/// `Arc<PackedWeightMem>` across a whole fold sweep (fig. 12–14 style)
/// instead of re-packing the matrix once per fold variant.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PackedWeightMem {
    bits: PackedMatrix,
}

impl PackedWeightMem {
    /// Pack a {0,1} weight matrix. Errors on entries outside {0,1} — the
    /// fast kernel falls back to the flat datapath in that case, so
    /// packed and unpacked runs stay bit-identical on any input.
    pub fn from_matrix(w: &Matrix) -> Result<PackedWeightMem> {
        Ok(PackedWeightMem { bits: PackedMatrix::from_matrix(w)? })
    }

    pub fn rows(&self) -> usize {
        self.bits.rows
    }

    pub fn cols(&self) -> usize {
        self.bits.cols
    }

    /// Matrix row `r` as packed words — the whole-row operand of
    /// [`pe_row_packed_xnor`](super::simd_elem::pe_row_packed_xnor) /
    /// [`pe_row_packed_binary`](super::simd_elem::pe_row_packed_binary).
    #[inline]
    pub fn row_words(&self, r: usize) -> &[u64] {
        self.bits.row_words(r)
    }

    /// The SIMD-bit memory word of PE `p` at address `nf * SF + sf` under
    /// the folding described by `params`, unpacked to lanes. Fold
    /// geometry is an argument rather than state (see the type docs);
    /// this accessor exists for layout tests and debugging, not the hot
    /// path.
    pub fn read(&self, params: &ValidatedParams, p: usize, addr: usize) -> Vec<i32> {
        let sf = params.synapse_fold();
        let (nf, s) = (addr / sf, addr % sf);
        let row = nf * params.pe + p;
        (0..params.simd).map(|l| self.bits.lane(row, s * params.simd + l)).collect()
    }

    /// Total weight bits stored (1 bit per lane).
    pub fn total_bits(&self) -> usize {
        self.bits.rows * self.bits.cols
    }

    /// Fault-injection hook, packed analogue of [`WeightMem::flip_bits`]:
    /// toggle `flips` seeded single bits (each one lane, since packed
    /// lanes are 1-bit). Inert unless called. Returns the flips applied.
    pub fn flip_bits(&mut self, seed: u64, flips: usize) -> usize {
        if self.bits.rows == 0 || self.bits.cols == 0 {
            return 0;
        }
        let mut rng = Pcg32::with_stream(seed, 0x77);
        for _ in 0..flips {
            let r = (rng.next_u64() % self.bits.rows as u64) as usize;
            let c = (rng.next_u64() % self.bits.cols as u64) as usize;
            self.bits.toggle(r, c);
        }
        flips
    }

    /// Lanes (bits) where this packing differs from `other`.
    pub fn diff_bits(&self, other: &PackedWeightMem) -> usize {
        debug_assert_eq!((self.rows(), self.cols()), (other.rows(), other.cols()));
        (0..self.rows())
            .map(|r| {
                self.bits
                    .row_words(r)
                    .iter()
                    .zip(other.bits.row_words(r))
                    .map(|(a, b)| (a ^ b).count_ones() as usize)
                    .sum::<usize>()
            })
            .sum()
    }

    /// Restore every bit from `golden` (the quarantine-exit scrub).
    pub fn scrub_from(&mut self, golden: &PackedWeightMem) -> Result<()> {
        if (self.rows(), self.cols()) != (golden.rows(), golden.cols()) {
            bail!("scrub: packed weight memory shapes differ");
        }
        self.bits = golden.bits.clone();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> crate::cfg::ValidatedParams {
        crate::cfg::DesignPoint::fc("t")
            .in_features(8)
            .out_features(4)
            .pe(2)
            .simd(4)
            .build()
            .unwrap()
    }

    fn matrix() -> Matrix {
        // rows r, cols c: value = 10*r + c, distinguishable everywhere
        let data: Vec<i32> = (0..4).flat_map(|r| (0..8).map(move |c| 10 * r + c)).collect();
        Matrix::new(4, 8, data).unwrap()
    }

    #[test]
    fn layout_matches_paper_eq2() {
        let p = params();
        let wm = WeightMem::from_matrix(&p, &matrix()).unwrap();
        assert_eq!(wm.depth, 8 * 4 / (4 * 2)); // Eq. (2) = 4
        // PE 0, addr = nf*SF+sf: nf=0 -> row 0; nf=1 -> row 2
        // SF = 8/4 = 2
        assert_eq!(wm.read(0, 0), &[0, 1, 2, 3]); // row 0, sf 0
        assert_eq!(wm.read(0, 1), &[4, 5, 6, 7]); // row 0, sf 1
        assert_eq!(wm.read(0, 2), &[20, 21, 22, 23]); // row 2, sf 0
        assert_eq!(wm.read(1, 2), &[30, 31, 32, 33]); // PE 1 -> row 3
    }

    #[test]
    fn fold_row_is_the_matrix_row() {
        // read_row(p, nf, SF) must equal matrix row nf*PE + p verbatim —
        // the contiguity argument the fast kernel's flat dot product
        // rests on.
        let p = params();
        let m = matrix();
        let wm = WeightMem::from_matrix(&p, &m).unwrap();
        let sf = p.synapse_fold();
        for nf in 0..p.neuron_fold() {
            for pe in 0..p.pe {
                assert_eq!(wm.read_row(pe, nf, sf), m.row(nf * p.pe + pe), "nf={nf} pe={pe}");
            }
        }
    }

    #[test]
    fn rejects_wrong_shape() {
        let p = params();
        assert!(WeightMem::from_matrix(&p, &Matrix::zeros(3, 8)).is_err());
    }

    #[test]
    fn total_bits() {
        let p = params();
        let wm = WeightMem::from_matrix(&p, &matrix()).unwrap();
        assert_eq!(wm.total_bits(4), 4 * 8 * 4); // rows*cols*bits
    }

    /// Bit matrix for the packed-memory tests (shape of `params()`).
    fn bit_matrix() -> Matrix {
        Matrix::new(4, 8, (0..32).map(|i| ((i * 5) % 3 == 0) as i32).collect()).unwrap()
    }

    #[test]
    fn packed_words_match_flat_memory() {
        // PackedWeightMem::read under a folding must agree word-for-word
        // with the flat WeightMem built for that folding, and row_words
        // must carry the matrix row bits verbatim.
        let p = params();
        let m = bit_matrix();
        let flat = WeightMem::from_matrix(&p, &m).unwrap();
        let packed = PackedWeightMem::from_matrix(&m).unwrap();
        assert_eq!((packed.rows(), packed.cols()), (m.rows, m.cols));
        for pe in 0..p.pe {
            for addr in 0..p.weight_mem_depth() {
                assert_eq!(
                    packed.read(&p, pe, addr),
                    flat.read(pe, addr),
                    "pe={pe} addr={addr}"
                );
            }
        }
        for r in 0..m.rows {
            for c in 0..m.cols {
                assert_eq!(
                    (packed.row_words(r)[c / 64] >> (c % 64)) & 1,
                    m.at(r, c) as u64,
                    "r={r} c={c}"
                );
            }
        }
    }

    #[test]
    fn packing_is_fold_independent() {
        // one packing serves two different foldings of the same matrix
        let m = bit_matrix();
        let packed = PackedWeightMem::from_matrix(&m).unwrap();
        for (pe, simd) in [(1usize, 8usize), (4, 2)] {
            let p = crate::cfg::DesignPoint::fc("t")
                .in_features(8)
                .out_features(4)
                .pe(pe)
                .simd(simd)
                .build()
                .unwrap();
            let flat = WeightMem::from_matrix(&p, &m).unwrap();
            for q in 0..pe {
                for addr in 0..p.weight_mem_depth() {
                    assert_eq!(
                        packed.read(&p, q, addr),
                        flat.read(q, addr),
                        "pe={pe} simd={simd} q={q} addr={addr}"
                    );
                }
            }
        }
        assert_eq!(packed.total_bits(), 32);
    }

    #[test]
    fn packed_rejects_nonbit_weights() {
        assert!(PackedWeightMem::from_matrix(&matrix()).is_err());
    }

    #[test]
    fn flip_bits_stays_in_domain_and_scrubs_back() {
        let p = params();
        let golden = WeightMem::from_matrix(&p, &bit_matrix()).unwrap();
        let mut wm = golden.clone();
        // unsigned 1-bit lanes: flips toggle within {0, 1}
        let applied = wm.flip_bits(5, 7, 1, false);
        assert_eq!(applied, 7);
        assert!(wm.diff_lanes(&golden) > 0, "an odd flip count must leave a difference");
        for pe in 0..p.pe {
            for addr in 0..p.weight_mem_depth() {
                for &v in wm.read(pe, addr) {
                    assert!(v == 0 || v == 1, "1-bit lane out of domain: {v}");
                }
            }
        }
        // same seed, same flips — corruption replays bit-for-bit
        let mut again = golden.clone();
        again.flip_bits(5, 7, 1, false);
        assert_eq!(again.diff_lanes(&wm), 0);
        wm.scrub_from(&golden).unwrap();
        assert_eq!(wm.diff_lanes(&golden), 0);
    }

    #[test]
    fn flip_bits_sign_extends_signed_lanes() {
        // 4-bit signed lanes (Standard): every flipped lane must stay in
        // [-8, 7], including flips of the sign bit
        let p = params();
        let golden = WeightMem::from_matrix(&p, &matrix()).unwrap();
        let mut wm = golden.clone();
        wm.flip_bits(11, 64, 4, true);
        for pe in 0..p.pe {
            for addr in 0..p.weight_mem_depth() {
                for &v in wm.read(pe, addr) {
                    assert!((-8..=7).contains(&v), "4-bit signed lane out of domain: {v}");
                }
            }
        }
        let mut bad_shape = WeightMem::from_matrix(&p, &matrix()).unwrap();
        let other = crate::cfg::DesignPoint::fc("t")
            .in_features(8)
            .out_features(4)
            .pe(1)
            .simd(8)
            .build()
            .unwrap();
        let golden_other = WeightMem::from_matrix(&other, &matrix()).unwrap();
        assert!(bad_shape.scrub_from(&golden_other).is_err(), "shape mismatch rejected");
    }

    #[test]
    fn packed_flip_bits_and_scrub() {
        let golden = PackedWeightMem::from_matrix(&bit_matrix()).unwrap();
        let mut pm = golden.clone();
        assert_eq!(pm.flip_bits(9, 5), 5);
        assert!(pm.diff_bits(&golden) > 0 && pm.diff_bits(&golden) <= 5);
        // flips land on real lanes: unpacking still agrees lane-by-lane
        // with some {0,1} matrix (tail padding untouched)
        let p = params();
        for pe in 0..p.pe {
            for addr in 0..p.weight_mem_depth() {
                for v in pm.read(&p, pe, addr) {
                    assert!(v == 0 || v == 1);
                }
            }
        }
        pm.scrub_from(&golden).unwrap();
        assert_eq!(pm.diff_bits(&golden), 0);
        assert_eq!(pm, golden);
    }
}
