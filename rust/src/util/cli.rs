//! Minimal command-line argument parser (no `clap` in the offline registry).
//!
//! Supports subcommands, `--flag`, `--key value`, `--key=value`, and
//! positional arguments, with typed accessors and generated usage text.

use std::collections::BTreeMap;

/// Parsed arguments for one (sub)command invocation.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub command: Option<String>,
    flags: BTreeMap<String, String>,
    positionals: Vec<String>,
}

/// Error with usage context.
#[derive(Debug)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse raw args (without argv[0]). The first non-flag token is the
    /// subcommand; everything else is `--key[=value]` or positional.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args, CliError> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                if rest.is_empty() {
                    // `--` ends option parsing
                    out.positionals.extend(it);
                    break;
                }
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else {
                    // lookahead: value unless next token is another flag
                    match it.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = it.next().unwrap();
                            out.flags.insert(rest.to_string(), v);
                        }
                        _ => {
                            out.flags.insert(rest.to_string(), "true".to_string());
                        }
                    }
                }
            } else if out.command.is_none() {
                out.command = Some(tok);
            } else {
                out.positionals.push(tok);
            }
        }
        Ok(out)
    }

    /// Parse from the process environment.
    pub fn from_env() -> Result<Args, CliError> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, CliError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError(format!("--{key} expects an integer, got {v:?}"))),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, CliError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError(format!("--{key} expects a number, got {v:?}"))),
        }
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Comma-separated list of integers, e.g. `--pes 2,4,8`.
    pub fn get_usize_list(&self, key: &str, default: &[usize]) -> Result<Vec<usize>, CliError> {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|t| {
                    t.trim()
                        .parse()
                        .map_err(|_| CliError(format!("--{key}: bad integer {t:?}")))
                })
                .collect(),
        }
    }

    /// Error out on unknown flags, given the set of recognized keys.
    pub fn check_known(&self, known: &[&str]) -> Result<(), CliError> {
        for k in self.flags.keys() {
            if !known.contains(&k.as_str()) {
                return Err(CliError(format!(
                    "unknown flag --{k}; known: {}",
                    known.iter().map(|s| format!("--{s}")).collect::<Vec<_>>().join(" ")
                )));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse(&["sweep", "--pe", "4", "--simd=8", "--verbose"]);
        assert_eq!(a.command.as_deref(), Some("sweep"));
        assert_eq!(a.get("pe"), Some("4"));
        assert_eq!(a.get("simd"), Some("8"));
        assert!(a.get_bool("verbose"));
    }

    #[test]
    fn typed_accessors() {
        let a = parse(&["run", "--n", "100", "--rate", "2.5"]);
        assert_eq!(a.get_usize("n", 0).unwrap(), 100);
        assert_eq!(a.get_f64("rate", 0.0).unwrap(), 2.5);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
        assert!(a.get_usize("rate", 0).is_err());
    }

    #[test]
    fn lists() {
        let a = parse(&["x", "--pes", "2,4,8"]);
        assert_eq!(a.get_usize_list("pes", &[]).unwrap(), vec![2, 4, 8]);
        assert_eq!(a.get_usize_list("none", &[1]).unwrap(), vec![1]);
    }

    #[test]
    fn positionals_and_doubledash() {
        let a = parse(&["run", "file1", "--", "--not-a-flag"]);
        assert_eq!(a.positionals(), &["file1", "--not-a-flag"]);
    }

    #[test]
    fn unknown_flag_rejected() {
        let a = parse(&["run", "--bogus", "1"]);
        assert!(a.check_known(&["n"]).is_err());
        assert!(a.check_known(&["bogus"]).is_ok());
    }
}
