//! Minimal JSON parser/serializer.
//!
//! The offline crate registry has no `serde`/`serde_json`, so this module
//! implements the subset of JSON the project needs: the artifact manifest,
//! trained weights, sweep reports and bench outputs. It is a full
//! RFC 8259 parser (objects, arrays, strings with escapes, numbers, bools,
//! null) with precise error positions; the writer emits deterministic,
//! compact or pretty output.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) so serialization is
/// deterministic — important for golden-file tests.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset and line/column.
#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub line: usize,
    pub col: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at line {}, col {}: {}", self.line, self.col, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- constructors -----------------------------------------------------

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn from_i64(v: i64) -> Json {
        Json::Num(v as f64)
    }

    // ---- accessors ---------------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(v) if v.fract() == 0.0 => Some(*v as i64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }

    pub fn as_i32(&self) -> Option<i32> {
        self.as_i64().and_then(|v| i32::try_from(v).ok())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Object field lookup; returns `Json::Null` for missing keys so
    /// chained lookups read cleanly.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(m) => m.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Array index lookup, `Json::Null` when out of range.
    pub fn at(&self, idx: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(idx).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Insert into an object (panics if not an object — builder use only).
    pub fn set(&mut self, key: &str, value: Json) -> &mut Json {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), value);
                self
            }
            _ => panic!("Json::set on non-object"),
        }
    }

    /// Decode a 2-D i32 matrix stored as array-of-arrays.
    pub fn as_matrix_i32(&self) -> Option<Vec<Vec<i32>>> {
        let rows = self.as_arr()?;
        let mut out = Vec::with_capacity(rows.len());
        for r in rows {
            let cols = r.as_arr()?;
            let mut row = Vec::with_capacity(cols.len());
            for c in cols {
                row.push(c.as_i32()?);
            }
            out.push(row);
        }
        Some(out)
    }

    /// Decode a 1-D i32 vector.
    pub fn as_vec_i32(&self) -> Option<Vec<i32>> {
        self.as_arr()?.iter().map(|v| v.as_i32()).collect()
    }

    /// Encode a 2-D i32 matrix.
    pub fn from_matrix_i32(m: &[Vec<i32>]) -> Json {
        Json::Arr(
            m.iter()
                .map(|r| Json::Arr(r.iter().map(|&v| Json::from_i64(v as i64)).collect()))
                .collect(),
        )
    }

    // ---- parse -------------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters after value"));
        }
        Ok(v)
    }

    // ---- write -------------------------------------------------------------

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with the given indent width.
    pub fn to_pretty(&self, indent: usize) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(indent), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(v) => write_num(out, *v),
            Json::Str(s) => write_str(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (k, v) in a.iter().enumerate() {
                    if k > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (k, (key, v)) in m.iter().enumerate() {
                    if k > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_str(out, key);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, v: f64) {
    if v.fract() == 0.0 && v.abs() < 9e15 {
        out.push_str(&format!("{}", v as i64));
    } else {
        out.push_str(&format!("{v}"));
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        let (mut line, mut col) = (1, 1);
        for &c in &self.b[..self.i.min(self.b.len())] {
            if c == b'\n' {
                line += 1;
                col = 1;
            } else {
                col += 1;
            }
        }
        JsonError { msg: msg.to_string(), line, col }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.i += 1;
                            let cp = self.hex4()?;
                            // surrogate pairs
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.peek() == Some(b'\\') {
                                    self.i += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    let c =
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(ch.ok_or_else(|| self.err("invalid \\u escape"))?);
                            continue; // hex4 advanced i already
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = &self.b[self.i..];
                    let text = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = text.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let hs = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("invalid \\u escape"))?;
        let v = u32::from_str_radix(hs, 16).map_err(|_| self.err("invalid \\u escape"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").at(0).as_i64(), Some(1));
        assert!(v.get("a").at(2).get("b").is_null());
        assert_eq!(v.get("c").as_str(), Some("x"));
        assert!(v.get("missing").is_null());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"arr":[1,2.5,true,null,"s"],"obj":{"k":-3}}"#;
        let v = Json::parse(src).unwrap();
        let compact = v.to_string();
        assert_eq!(Json::parse(&compact).unwrap(), v);
        let pretty = v.to_pretty(2);
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
    }

    #[test]
    fn matrix_codec() {
        let m = vec![vec![1, -2], vec![3, 4]];
        let j = Json::from_matrix_i32(&m);
        assert_eq!(j.as_matrix_i32(), Some(m));
    }

    #[test]
    fn errors_have_positions() {
        let e = Json::parse("{\n  \"a\": }").unwrap_err();
        assert_eq!(e.line, 2);
        assert!(e.msg.contains("expected"));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn deterministic_object_order() {
        let v = Json::parse(r#"{"b":1,"a":2}"#).unwrap();
        assert_eq!(v.to_string(), r#"{"a":2,"b":1}"#);
    }
}
