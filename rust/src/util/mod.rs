//! Substrate utilities built from scratch for the offline environment
//! (no serde/clap/rand/criterion in the registry — DESIGN.md §8).

pub mod cli;
pub mod json;
pub mod rng;
pub mod stats;
pub mod table;
