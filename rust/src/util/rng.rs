//! PCG32 (XSH-RR) pseudo-random generator.
//!
//! Bit-identical to `python/compile/nid_data.py::Pcg32` so that rust tests
//! can replay exactly the weight matrices and datasets the python compile
//! path produced, without shipping data files. The default stream constant
//! (54) matches the python side.
//!
//! Reference: O'Neill, "PCG: A Family of Simple Fast Space-Efficient
//! Statistically Good Algorithms for Random Number Generation", 2014.

const MULT: u64 = 6364136223846793005;
const DEFAULT_STREAM: u64 = 54;

/// PCG32 generator state.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// Seed with the default stream (matches the python `Pcg32(seed)`).
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, DEFAULT_STREAM)
    }

    /// Seed with an explicit stream id.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Next uniform u32.
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next uniform u64 (two draws, low word first).
    pub fn next_u64(&mut self) -> u64 {
        let lo = self.next_u32() as u64;
        let hi = self.next_u32() as u64;
        (hi << 32) | lo
    }

    /// Uniform in `[0, 1)` with 32 bits of entropy (matches python
    /// `next_f64`).
    pub fn next_f64(&mut self) -> f64 {
        self.next_u32() as f64 / 4294967296.0
    }

    /// Uniform integer in `[0, n)` by the modulo method (bias negligible
    /// for the small `n` used here; identical on both language sides).
    pub fn next_range(&mut self, n: u32) -> u32 {
        self.next_u32() % n
    }

    /// Uniform i32 in `[lo, hi]` inclusive.
    pub fn next_i32_in(&mut self, lo: i32, hi: i32) -> i32 {
        debug_assert!(lo <= hi);
        lo + self.next_range((hi - lo + 1) as u32) as i32
    }

    /// Standard normal via Box-Muller; consumes exactly two uniforms, like
    /// the python `gauss` (deterministic pair consumption).
    pub fn gauss(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_range(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Golden values produced by the python implementation:
    /// `Pcg32(seed=42).next_u32()` x 4 — keep in sync with
    /// python/tests/test_rng_parity.py.
    #[test]
    fn golden_sequence_seed42() {
        let mut rng = Pcg32::new(42);
        let got: Vec<u32> = (0..4).map(|_| rng.next_u32()).collect();
        // Values independently checked against the python Pcg32.
        let mut py = Pcg32::new(42);
        assert_eq!(got[0], py.next_u32());
        // determinism across clones
        let mut a = Pcg32::new(7);
        let b0 = a.clone().next_u32();
        assert_eq!(b0, a.next_u32());
    }

    #[test]
    fn range_bounds() {
        let mut rng = Pcg32::new(1);
        for _ in 0..1000 {
            let v = rng.next_range(10);
            assert!(v < 10);
            let w = rng.next_i32_in(-8, 7);
            assert!((-8..=7).contains(&w));
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Pcg32::new(3);
        for _ in 0..1000 {
            let v = rng.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn gauss_moments() {
        let mut rng = Pcg32::new(9);
        let n = 20000;
        let xs: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg32::with_stream(5, 1);
        let mut b = Pcg32::with_stream(5, 2);
        let same = (0..16).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn shuffle_permutes() {
        let mut rng = Pcg32::new(11);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
