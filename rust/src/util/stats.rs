//! Descriptive statistics for bench reports and critical-path tables.

/// Summary statistics over a sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub min: f64,
    pub max: f64,
    pub mean: f64,
    pub median: f64,
    pub stddev: f64,
}

impl Summary {
    /// Compute summary statistics. Returns `None` for an empty sample.
    pub fn of(xs: &[f64]) -> Option<Summary> {
        if xs.is_empty() {
            return None;
        }
        let n = xs.len();
        let mut sorted: Vec<f64> = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let min = sorted[0];
        let max = sorted[n - 1];
        let mean = xs.iter().sum::<f64>() / n as f64;
        let median = if n % 2 == 1 {
            sorted[n / 2]
        } else {
            (sorted[n / 2 - 1] + sorted[n / 2]) / 2.0
        };
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Some(Summary { n, min, max, mean, median, stddev: var.sqrt() })
    }

    /// Percentile by nearest-rank (p in [0, 100]): the smallest sample
    /// with at least p% of the data at or below it — rank `ceil(p/100·n)`
    /// (1-based), clamped to [1, n] so p=0 yields the minimum.
    pub fn percentile(xs: &[f64], p: f64) -> Option<f64> {
        if xs.is_empty() {
            return None;
        }
        let mut sorted: Vec<f64> = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = sorted.len();
        let rank = ((p / 100.0) * n as f64).ceil() as usize;
        Some(sorted[rank.clamp(1, n) - 1])
    }
}

/// Online mean/variance (Welford) — used in the bench harness hot loop to
/// avoid storing every sample.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert_eq!(s.mean, 2.5);
        assert_eq!(s.median, 2.5);
        assert!((s.stddev - 1.118).abs() < 1e-3);
    }

    #[test]
    fn summary_odd_median() {
        let s = Summary::of(&[5.0, 1.0, 3.0]).unwrap();
        assert_eq!(s.median, 3.0);
    }

    #[test]
    fn summary_empty() {
        assert!(Summary::of(&[]).is_none());
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(Summary::percentile(&xs, 0.0), Some(1.0));
        assert_eq!(Summary::percentile(&xs, 100.0), Some(100.0));
        let p50 = Summary::percentile(&xs, 50.0).unwrap();
        assert!((p50 - 50.0).abs() <= 1.0);
    }

    /// True nearest-rank edge cases: rank `ceil(p/100·n)` at the sample
    /// sizes where the old `round(p/100·(n−1))` formula went wrong (n=2,
    /// p50 must be the MIN — at most half the data lies at or below it).
    #[test]
    fn percentile_nearest_rank_edge_cases() {
        // n = 1: every percentile is the single sample
        let one = [7.0];
        for p in [0.0, 50.0, 99.0, 100.0] {
            assert_eq!(Summary::percentile(&one, p), Some(7.0), "n=1 p={p}");
        }
        // n = 2: p50 -> rank ceil(1.0) = 1 -> min (the old formula
        // returned the max); p99/p100 -> max; p0 -> min
        let two = [10.0, 20.0];
        assert_eq!(Summary::percentile(&two, 0.0), Some(10.0));
        assert_eq!(Summary::percentile(&two, 50.0), Some(10.0));
        assert_eq!(Summary::percentile(&two, 99.0), Some(20.0));
        assert_eq!(Summary::percentile(&two, 100.0), Some(20.0));
        // n = 100: ranks land exactly on ceil(p) for integer samples
        let hundred: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(Summary::percentile(&hundred, 50.0), Some(50.0));
        assert_eq!(Summary::percentile(&hundred, 99.0), Some(99.0));
        assert_eq!(Summary::percentile(&hundred, 100.0), Some(100.0));
        assert_eq!(Summary::percentile(&hundred, 0.0), Some(1.0));
    }

    #[test]
    fn welford_matches_summary() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let s = Summary::of(&xs).unwrap();
        assert!((w.mean() - s.mean).abs() < 1e-12);
        assert!((w.stddev() - s.stddev).abs() < 1e-12);
        assert_eq!(w.min(), s.min);
        assert_eq!(w.max(), s.max);
    }
}
