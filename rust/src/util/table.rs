//! Aligned plain-text tables — how the bench harness prints the paper's
//! tables and figure series.

/// A simple column-aligned table builder.
#[derive(Debug, Default, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Table {
        Table { header: header.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row has {} cells, header has {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.chars().count();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        let line = |out: &mut String, cells: &[String]| {
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    out.push_str("  ");
                }
                out.push_str(c);
                for _ in c.chars().count()..width[i] {
                    out.push(' ');
                }
            }
            // trim right-pad of last column
            while out.ends_with(' ') {
                out.pop();
            }
            out.push('\n');
        };
        line(&mut out, &self.header);
        let total: usize = width.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            line(&mut out, row);
        }
        out
    }
}

/// Format a float with a fixed number of decimals, trimming "-0.000".
pub fn fnum(v: f64, decimals: usize) -> String {
    let s = format!("{v:.decimals$}");
    if s.starts_with("-0.") && s[1..].parse::<f64>() == Ok(0.0) {
        s[1..].to_string()
    } else {
        s
    }
}

/// Format seconds as m'ss" like the paper's Table 7 synthesis times.
pub fn fmin(seconds: f64) -> String {
    let total = seconds.round() as i64;
    format!("{}'{:02}\"", total / 60, total % 60)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(vec!["name", "LUTs", "FFs"]);
        t.row(vec!["rtl", "120", "48"]);
        t.row(vec!["hls", "1520", "2311"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("name"));
        assert!(lines[2].starts_with("rtl"));
        // column alignment: "LUTs" column starts at same offset in all rows
        let off = lines[0].find("LUTs").unwrap();
        assert_eq!(lines[2].find("120"), Some(off));
    }

    #[test]
    #[should_panic]
    fn wrong_arity_panics() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }

    #[test]
    fn fnum_and_fmin() {
        assert_eq!(fnum(1.42351, 3), "1.424");
        assert_eq!(fnum(-0.0001, 3), "0.000");
        assert_eq!(fmin(2325.0), "38'45\"");
        assert_eq!(fmin(103.0), "1'43\"");
    }
}
