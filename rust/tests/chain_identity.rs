//! Bit-identity between the two *chain* kernels (DESIGN.md §Chain fast
//! kernel): the next-event production kernel (`sim::run_chain*`, behind
//! every chain evaluation) must reproduce the per-cycle oracle
//! (`sim::MvuChain`) field-for-field — output vectors, pipeline-fill and
//! exact total cycle counts, and per-layer stall/slot counters — over
//! the NID MLP grid (all layer shapes x fold variants x both the
//! Standard and packed-Xnor datapaths), under periodic/random/schedule
//! stall patterns on both chain endpoints, across FIFO depths
//! {1, 2, 32}, and including agreement on deadlock failures. Run under
//! `--release` in CI as well, alongside `kernel_identity`: the packed
//! SWAR row kernels rely on wrapping identities that debug_asserts and
//! debug overflow checks can mask in dev builds.

use finn_mvu::cfg::{DesignPoint, SimdType, ValidatedParams};
use finn_mvu::explore::{stimulus_seed, stimulus_thresholds, stimulus_weights};
use finn_mvu::proptest::{check, Config, Gen};
use finn_mvu::quant::{Matrix, Thresholds};
use finn_mvu::sim::{run_chain, run_chain_stalled, MvuChain, StallPattern};

type Layer = (ValidatedParams, Matrix, Option<Thresholds>);

/// The Table 6 NID MLP geometry (600-64-64-64-1) under an explicit
/// folding and SIMD type, with the engine's canonical stimulus: weights
/// from each layer's fold-independent seed, thresholds between layers
/// (1-bit under Xnor so inter-layer streams stay bits, 2-bit under
/// Standard like the trained network).
fn nid_variant(ty: SimdType, folds: &[(usize, usize); 4]) -> Vec<Layer> {
    let (wb, ib, inner_ob) = match ty {
        SimdType::Xnor => (1u32, 1u32, 1u32),
        SimdType::BinaryWeights => (1, 2, 1),
        SimdType::Standard => (2, 2, 2),
    };
    let shape = [(600usize, 64usize), (64, 64), (64, 64), (64, 1)];
    shape
        .iter()
        .zip(folds)
        .enumerate()
        .map(|(i, (&(fin, fout), &(pe, simd)))| {
            let ob = if i + 1 < shape.len() { inner_ob } else { 0 };
            let p = DesignPoint::fc(&format!("nid{i}_p{pe}s{simd}"))
                .in_features(fin)
                .out_features(fout)
                .pe(pe)
                .simd(simd)
                .simd_type(ty)
                .precision(wb, ib, ob)
                .build()
                .expect("NID fold variants are legal");
            let seed = stimulus_seed(&p);
            let w = stimulus_weights(&p, seed.wrapping_add(i as u64));
            let th = stimulus_thresholds(&p, seed ^ 0x6a09_e667_f3bc_c909);
            (p, w, th)
        })
        .collect()
}

fn nid_inputs(ty: SimdType, n: usize, seed: u64) -> Vec<Vec<i32>> {
    let mut rng = finn_mvu::util::rng::Pcg32::new(seed);
    (0..n)
        .map(|_| {
            (0..600)
                .map(|_| match ty {
                    SimdType::Xnor => rng.next_range(2) as i32,
                    _ => rng.next_range(4) as i32,
                })
                .collect()
        })
        .collect()
}

fn assert_identical(layers: &[Layer], inputs: &[Vec<i32>], in_s: &StallPattern,
                    out_s: &StallPattern, depth: usize, label: &str) {
    let fast = run_chain_stalled(layers, inputs, in_s.clone(), out_s.clone(), depth);
    let oracle = MvuChain::with_fifo_depth(layers, depth)
        .and_then(|mut c| c.run_stalled(inputs, in_s.clone(), out_s.clone()));
    match (fast, oracle) {
        (Ok(a), Ok(b)) => assert_eq!(a, b, "{label}"),
        (Err(a), Err(b)) => assert_eq!(a.to_string(), b.to_string(), "{label}"),
        (a, b) => panic!(
            "{label}: one kernel failed: fast {:?} vs oracle {:?}",
            a.map(|r| r.exec_cycles),
            b.map(|r| r.exec_cycles)
        ),
    }
}

/// The full NID grid: every layer shape under two fold variants, the
/// Standard (flat rows) and Xnor (packed rows) datapaths, six endpoint
/// flow scenarios and three FIFO depths.
#[test]
fn chain_kernels_identical_over_nid_grid() {
    let fold_variants: [[(usize, usize); 4]; 2] = [
        [(64, 50), (16, 32), (16, 32), (1, 8)], // the paper's folding
        [(16, 25), (8, 16), (4, 8), (1, 2)],    // a slower re-folding
    ];
    let scenarios: Vec<(StallPattern, StallPattern)> = vec![
        (StallPattern::None, StallPattern::None),
        (StallPattern::Periodic { period: 5, duty: 2, phase: 1 }, StallPattern::None),
        (StallPattern::None, StallPattern::Periodic { period: 4, duty: 2, phase: 0 }),
        (
            StallPattern::Periodic { period: 7, duty: 3, phase: 2 },
            StallPattern::Periodic { period: 5, duty: 3, phase: 1 },
        ),
        (
            StallPattern::Random { seed: 91, p_num: 100 },
            StallPattern::Random { seed: 92, p_num: 140 },
        ),
        (
            StallPattern::Schedule(vec![true, false, false, true, false]),
            StallPattern::Periodic { period: 3, duty: 1, phase: 0 },
        ),
    ];
    let mut runs = 0usize;
    for ty in [SimdType::Standard, SimdType::Xnor] {
        for (v, folds) in fold_variants.iter().enumerate() {
            let layers = nid_variant(ty, folds);
            let inputs = nid_inputs(ty, 3, 100 + v as u64);
            for (s, (in_s, out_s)) in scenarios.iter().enumerate() {
                for depth in [1usize, 2, 32] {
                    assert_identical(
                        &layers,
                        &inputs,
                        in_s,
                        out_s,
                        depth,
                        &format!("{ty} variant {v} scenario {s} depth {depth}"),
                    );
                    runs += 1;
                }
            }
        }
    }
    assert_eq!(runs, 2 * 2 * 6 * 3);
}

/// The blocked multi-vector chain datapath (DESIGN.md §Batched
/// datapath) across batch sizes straddling the blocking sweet spot: the
/// fast kernel precomputes every stage's row outputs for the whole
/// batch with the blocked kernel and replays them through the
/// cycle-exact control machinery, the oracle steps vector-by-vector —
/// the reports must still match field for field, on the ideal flow and
/// under endpoint stalls.
#[test]
fn chain_kernels_identical_across_batch_sizes() {
    let paper_folds = [(64usize, 50usize), (16, 32), (16, 32), (1, 8)];
    for ty in [SimdType::Standard, SimdType::Xnor] {
        let layers = nid_variant(ty, &paper_folds);
        let all = nid_inputs(ty, 33, 4242);
        for b in [1usize, 2, 31, 32, 33] {
            assert_identical(
                &layers,
                &all[..b],
                &StallPattern::None,
                &StallPattern::None,
                2,
                &format!("{ty} batch {b} ideal"),
            );
        }
        // one stalled flow at the blocking boundary: batching must not
        // perturb the stepped control path either
        assert_identical(
            &layers,
            &all[..32],
            &StallPattern::Periodic { period: 5, duty: 2, phase: 1 },
            &StallPattern::Random { seed: 17, p_num: 120 },
            2,
            &format!("{ty} batch 32 stalled"),
        );
    }
}

/// Deadlock agreement: a sink that never asserts TREADY and a source
/// that never asserts TVALID must fail both kernels with the *same*
/// structured message (same cycle count at the shared bound).
#[test]
fn chain_kernels_agree_on_deadlocks() {
    let small = |seed: u64| -> Vec<Layer> {
        let p0 = DesignPoint::fc("d0")
            .in_features(8)
            .out_features(4)
            .pe(2)
            .simd(4)
            .precision(2, 2, 2)
            .build()
            .unwrap();
        let p1 = DesignPoint::fc("d1")
            .in_features(4)
            .out_features(2)
            .pe(1)
            .simd(2)
            .precision(2, 2, 0)
            .build()
            .unwrap();
        [p0, p1]
            .into_iter()
            .enumerate()
            .map(|(i, p)| {
                let w = stimulus_weights(&p, seed + i as u64);
                let th = stimulus_thresholds(&p, seed ^ 0xabcd);
                (p, w, th)
            })
            .collect()
    };
    let layers = small(7);
    let inputs: Vec<Vec<i32>> = vec![(0..8).map(|i| i % 4).collect()];
    let never = StallPattern::Periodic { period: 1, duty: 1, phase: 0 };
    // never-ready sink: the chain jams on output backpressure
    assert_identical(&layers, &inputs, &StallPattern::None, &never, 2, "dead sink");
    // never-valid source: the chain idles forever waiting for data
    assert_identical(&layers, &inputs, &never, &StallPattern::None, 2, "dead source");
}

/// Property: arbitrary short chains (random legal folds, optional
/// thresholds, any stall pattern the public API accepts, FIFO depths
/// 1..=6) — identical reports or identical failures.
#[test]
fn prop_chain_kernels_identical() {
    fn arb_stall(g: &mut Gen) -> StallPattern {
        match g.usize_in(0, 3) {
            0 => StallPattern::None,
            1 => {
                let period = g.usize_in(1, 8);
                StallPattern::Periodic {
                    period,
                    duty: g.usize_in(0, period.min(6)),
                    phase: g.usize_in(0, 5),
                }
            }
            2 => StallPattern::Random {
                seed: g.rng.next_u64(),
                p_num: g.usize_in(0, 200) as u32,
            },
            _ => StallPattern::Schedule((0..g.usize_in(0, 8)).map(|_| g.chance(128)).collect()),
        }
    }
    check("fast chain == oracle chain", Config::cases(40), |g| {
        let ty = *g.choose(&SimdType::ALL);
        let (wb, ib) = match ty {
            SimdType::Xnor => (1u32, 1u32),
            SimdType::BinaryWeights => (1, 2),
            SimdType::Standard => (2, 2),
        };
        let n_layers = g.usize_in(2, 3);
        let mut dims = vec![g.usize_in(2, 20)];
        for _ in 0..n_layers {
            dims.push(g.usize_in(1, 10));
        }
        let mut layers: Vec<Layer> = Vec::new();
        for i in 0..n_layers {
            let (fin, fout) = (dims[i], dims[i + 1]);
            let inner = i + 1 < n_layers;
            // inner layers must threshold so the next layer's input stays
            // in range (bits under Xnor)
            let ob = if inner {
                if matches!(ty, SimdType::Xnor) {
                    1
                } else {
                    2
                }
            } else {
                0
            };
            let p = DesignPoint::fc(&format!("pc{i}"))
                .in_features(fin)
                .out_features(fout)
                .pe(g.divisor_of(fout))
                .simd(g.divisor_of(fin))
                .simd_type(ty)
                .precision(wb, ib, ob)
                .build()
                .expect("generated folds are divisors, hence legal");
            let w = stimulus_weights(&p, g.rng.next_u64());
            let th = stimulus_thresholds(&p, g.rng.next_u64());
            layers.push((p, w, th));
        }
        let n_vec = g.usize_in(0, 4);
        let inputs: Vec<Vec<i32>> = (0..n_vec)
            .map(|_| {
                (0..dims[0])
                    .map(|_| match ty {
                        SimdType::Xnor => g.i32_in(0, 1),
                        _ => g.i32_in(0, 3),
                    })
                    .collect()
            })
            .collect();
        let in_s = arb_stall(g);
        let out_s = arb_stall(g);
        let depth = g.usize_in(1, 6);
        let fast = run_chain_stalled(&layers, &inputs, in_s.clone(), out_s.clone(), depth);
        let oracle = MvuChain::with_fifo_depth(&layers, depth)
            .and_then(|mut c| c.run_stalled(&inputs, in_s.clone(), out_s.clone()));
        match (fast, oracle) {
            (Ok(a), Ok(b)) => {
                if a != b {
                    return Err(format!(
                        "{ty} depth={depth} ({in_s:?}/{out_s:?}): fast {a:?} != oracle {b:?}"
                    ));
                }
                Ok(())
            }
            (Err(a), Err(b)) => {
                if a.to_string() != b.to_string() {
                    return Err(format!(
                        "{ty} depth={depth}: error divergence: fast {a:#} vs oracle {b:#}"
                    ));
                }
                Ok(())
            }
            (a, b) => Err(format!(
                "{ty} depth={depth} ({in_s:?}/{out_s:?}): one kernel failed: fast {:?} vs \
                 oracle {:?}",
                a.map(|r| r.exec_cycles),
                b.map(|r| r.exec_cycles)
            )),
        }
    });
}

/// The ideal-flow default entry point (`run_chain`, default FIFO depth)
/// agrees with the oracle at its default depth too.
#[test]
fn default_entry_point_matches_oracle_default() {
    for ty in [SimdType::Standard, SimdType::Xnor] {
        let layers = nid_variant(ty, &[(64, 50), (16, 32), (16, 32), (1, 8)]);
        let inputs = nid_inputs(ty, 2, 55);
        let fast = run_chain(&layers, &inputs).unwrap();
        let oracle = MvuChain::new(&layers).unwrap().run(&inputs).unwrap();
        assert_eq!(fast, oracle, "{ty}");
    }
}
