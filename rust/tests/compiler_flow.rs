//! Property tests over the FINN-style compiler flow: lowering and folding
//! must preserve semantics for arbitrary frontend graphs, and folding must
//! always produce legal configurations.

use finn_mvu::ir::{Graph, Op, TensorInfo};
use finn_mvu::passes::{
    analyze, execute_reference, fold_to_target, folding_is_legal, lower_to_hw,
};
use finn_mvu::proptest::{check, Config, Gen};
use finn_mvu::quant::{Matrix, Thresholds};

/// Random frontend graph: optional conv head + 1-3 fc layers with
/// optional threshold activations.
fn arb_frontend(g: &mut Gen) -> (Graph, usize) {
    let with_conv = g.chance(128);
    let (mut graph, mut elems, input_len) = if with_conv {
        let ic = g.usize_in(1, 3);
        let dim = g.usize_in(3, 6);
        let kd = g.usize_in(1, dim.min(3));
        let oc = g.usize_in(1, 6);
        let cols = kd * kd * ic;
        let w = Matrix::new(oc, cols, g.vec_i32(oc * cols, -4, 3)).unwrap();
        let mut gr = Graph::new(TensorInfo { elems: ic * dim * dim, vectors: 1, bits: 2 });
        gr.push(
            "conv",
            Op::Conv { weights: w, ifm_ch: ic, ifm_dim: dim, ofm_ch: oc, kernel_dim: kd },
        );
        (gr, oc, ic * dim * dim)
    } else {
        let elems = g.usize_in(2, 24);
        (Graph::new(TensorInfo { elems, vectors: 1, bits: 2 }), elems, 0)
    };
    let input_len = if with_conv { input_len } else { elems };
    let n_fc = g.usize_in(1, 3);
    for i in 0..n_fc {
        // a MultiThreshold can only absorb into a preceding MVU/MatMul
        if !graph.is_empty() && g.chance(128) {
            let steps = g.usize_in(1, 3);
            let rows: Vec<Vec<i32>> = (0..elems)
                .map(|_| {
                    let mut t = g.vec_i32(steps, -30, 30);
                    t.sort();
                    t
                })
                .collect();
            graph.push(
                &format!("act{i}"),
                Op::MultiThreshold { thresholds: Thresholds::from_rows(&rows).unwrap() },
            );
        }
        let out = g.usize_in(1, 12);
        let w = Matrix::new(out, elems, g.vec_i32(out * elems, -4, 3)).unwrap();
        graph.push(&format!("fc{i}"), Op::MatMul { weights: w });
        elems = out;
    }
    (graph, input_len)
}

#[test]
fn prop_lowering_preserves_semantics() {
    check("lower-preserves", Config::cases(40), |g| {
        let (graph, input_len) = arb_frontend(g);
        // MultiThreshold directly after input cannot absorb -> legal graphs
        // here always start with conv or matmul, so lowering must succeed.
        let hw = lower_to_hw(&graph).map_err(|e| e.to_string())?;
        if !hw.is_hw_only() {
            return Err("not hw-only after lowering".into());
        }
        let inputs: Vec<Vec<i32>> =
            (0..2).map(|_| g.vec_i32(input_len, 0, 3)).collect();
        let a = execute_reference(&graph, &inputs).map_err(|e| e.to_string())?;
        let b = execute_reference(&hw, &inputs).map_err(|e| e.to_string())?;
        if a != b {
            return Err("lowering changed the computation".into());
        }
        Ok(())
    });
}

#[test]
fn prop_folding_legal_and_semantics_preserving() {
    check("fold-legal", Config::cases(30), |g| {
        let (graph, input_len) = arb_frontend(g);
        let hw = lower_to_hw(&graph).map_err(|e| e.to_string())?;
        let target = g.usize_in(1, 200);
        let budget = g.usize_in(2_000, 2_000_000);
        let rep = fold_to_target(&hw, target, budget).map_err(|e| e.to_string())?;
        if !folding_is_legal(&rep.graph) {
            return Err(format!("illegal folding at target {target} budget {budget}"));
        }
        let inputs: Vec<Vec<i32>> = (0..2).map(|_| g.vec_i32(input_len, 0, 3)).collect();
        let a = execute_reference(&hw, &inputs).map_err(|e| e.to_string())?;
        let b = execute_reference(&rep.graph, &inputs).map_err(|e| e.to_string())?;
        if a != b {
            return Err("folding changed the computation".into());
        }
        Ok(())
    });
}

#[test]
fn prop_tighter_budget_never_faster() {
    check("budget-monotone", Config::cases(20), |g| {
        let (graph, _) = arb_frontend(g);
        let hw = lower_to_hw(&graph).map_err(|e| e.to_string())?;
        let loose = fold_to_target(&hw, 1, 1_000_000).map_err(|e| e.to_string())?;
        let tight =
            fold_to_target(&hw, 1, loose.total_luts.saturating_sub(1).max(100))
                .map_err(|e| e.to_string())?;
        if tight.bottleneck_cycles < loose.bottleneck_cycles {
            return Err(format!(
                "tighter budget got faster: {} < {}",
                tight.bottleneck_cycles, loose.bottleneck_cycles
            ));
        }
        Ok(())
    });
}

#[test]
fn analyze_reports_every_mvu() {
    let mut g = Gen::new(7, 32);
    let (graph, _) = arb_frontend(&mut g);
    let hw = lower_to_hw(&graph).unwrap();
    let n_mvu = hw.nodes.iter().filter(|n| n.op.name() == "MVU").count();
    let rep = analyze(&hw).unwrap();
    assert_eq!(rep.layers.len(), n_mvu);
    assert!(rep.layers.iter().all(|l| l.luts_rtl > 0 && l.delay_rtl_ns > 0.0));
}
