//! Fault-injection and fault-tolerant serving for the simulated
//! accelerator card (DESIGN.md §Device subsystem, fault model):
//!
//!   * zero-fault byte-identity — an empty [`FaultPlan`] (and every
//!     robustness knob at its default) leaves the `DeviceSummary` JSON
//!     byte-identical to the pre-fault subsystem, with no `fault` or
//!     `trace_dropped` keys;
//!   * seeded-fault determinism — a faulty scenario is byte-identical
//!     across repeated runs and across engine thread counts {1, 2, 8};
//!   * request conservation — under every policy x fault mix,
//!     `completed + timed_out + dropped == offered`;
//!   * the degradation behaviors themselves: deadline expiry behind a
//!     hang, load shedding during a brownout, watchdog quarantine of a
//!     straggler, and checked-dispatch detection of weight corruption
//!     (vs. silent service without the check);
//!   * the `--faults` CLI DSL parses and rejects as documented.
//!
//! Run in CI under `--release` alongside the kernel-identity suites.

use finn_mvu::cfg::{DesignPoint, ValidatedParams};
use finn_mvu::device::{
    run_card, run_card_faulty, run_card_faulty_traced, ArrivalProcess, DeviceConfig, Fault,
    FaultPlan, HealthPolicy, PolicyKind, RetryPolicy, ServiceProfile, ShedPolicy,
};
use finn_mvu::eval::{DeviceRequest, Session};

/// The cheap fc MVU the device property tests use (16x8, PE 4, SIMD 8).
fn point() -> ValidatedParams {
    DesignPoint::fc("faulty").in_features(16).out_features(8).pe(4).simd(8).build().unwrap()
}

/// Calibrated-profile stand-in: 4b + 5 cycles for a block of b <= 8.
fn profile() -> ServiceProfile {
    ServiceProfile::new((1..=8).map(|b| 4 * b + 5).collect()).unwrap()
}

fn cfg(units: usize, policy: PolicyKind, gap: f64, requests: usize) -> DeviceConfig {
    let mut c = DeviceConfig::new(units, policy, ArrivalProcess::Poisson { mean_gap: gap });
    c.requests = requests;
    c.seed = 11;
    c
}

fn policies() -> Vec<PolicyKind> {
    vec![
        PolicyKind::RoundRobin,
        PolicyKind::LeastLoaded,
        PolicyKind::BatchAware { block: 8, max_wait: 64 },
    ]
}

#[test]
fn zero_fault_plan_is_byte_identical_to_the_pre_fault_card() {
    let mut base = cfg(3, PolicyKind::LeastLoaded, 5.0, 500);
    base.trace_every = 200;
    let plain = run_card(&base, &mut profile()).unwrap().to_json().to_string();
    assert!(!plain.contains("\"fault\""), "healthy summary must not carry a fault section");
    assert!(!plain.contains("trace_dropped"), "untruncated trace must not advertise drops");

    // an explicitly attached empty plan takes the same code path
    let mut idle = base.clone();
    idle.faults = FaultPlan::none();
    assert_eq!(
        run_card(&idle, &mut profile()).unwrap().to_json().to_string(),
        plain,
        "empty fault plan perturbed the summary"
    );

    // robustness machinery armed but never firing: the summary gains a
    // zeroed fault section and changes in no other byte
    let mut armed = base.clone();
    armed.deadline = Some(1 << 40);
    armed.retry = RetryPolicy { max_attempts: 3, ..RetryPolicy::default() };
    let mut s = run_card_faulty(&armed, &mut profile(), None).unwrap();
    let f = s.fault.take().expect("robust config must carry a fault section");
    assert_eq!(f.completed, f.offered, "nothing fired, nothing may be lost");
    assert_eq!(
        (f.hangs, f.deaths, f.stragglers, f.corruptions, f.retries, f.timed_out, f.dropped()),
        (0, 0, 0, 0, 0, 0, 0),
        "idle robustness machinery must count nothing"
    );
    assert_eq!(
        s.to_json().to_string(),
        plain,
        "armed-but-idle robustness changed bytes outside the fault section"
    );
}

#[test]
fn faulty_runs_are_byte_deterministic() {
    let mut c = cfg(3, PolicyKind::LeastLoaded, 4.0, 600);
    c.trace_every = 100;
    c.faults = FaultPlan {
        faults: vec![
            Fault::Hang { unit: 0, at: 150, cycles: 300 },
            Fault::Death { unit: 2, at: 700 },
            Fault::Straggler { unit: 1, from: 200, until: 1_200, factor: 3.0 },
        ],
        seed: 5,
    };
    c.deadline = Some(400);
    c.retry = RetryPolicy { max_attempts: 3, ..RetryPolicy::default() };
    c.shed = ShedPolicy::RejectNew { min_live: 2, max_depth: 32 };
    let a = run_card_faulty(&c, &mut profile(), None).unwrap().to_json().to_string();
    let b = run_card_faulty(&c, &mut profile(), None).unwrap().to_json().to_string();
    assert_eq!(a, b, "same seed + same plan must be byte-identical");
    assert!(a.contains("\"fault\""), "faulty summary must carry the fault section");

    // seeded random plans are themselves deterministic
    assert_eq!(FaultPlan::random(5, 4, 2_000, 8), FaultPlan::random(5, 4, 2_000, 8));
    assert_ne!(FaultPlan::random(5, 4, 2_000, 8), FaultPlan::random(6, 4, 2_000, 8));
}

#[test]
fn faulty_summaries_are_byte_identical_across_engine_thread_counts() {
    let req = {
        let mut r = DeviceRequest::nid(4);
        r.card.policy = PolicyKind::BatchAware { block: 4, max_wait: 128 };
        r.card.seed = 7;
        r.card.requests = 1200;
        r.card.trace_every = 500;
        r
    }
    .with_faults(FaultPlan {
        faults: vec![
            Fault::Hang { unit: 2, at: 3_000, cycles: 800 },
            Fault::Death { unit: 1, at: 6_000 },
        ],
        seed: 21,
    })
    .with_deadline(4_000)
    .with_retries(RetryPolicy { max_attempts: 3, ..RetryPolicy::default() });
    let baseline = {
        let s = Session::with_threads(1);
        let json = s.evaluate_device(&req).unwrap().to_json().to_string();
        // second run through the same session: cached, same bytes
        assert_eq!(s.evaluate_device(&req).unwrap().to_json().to_string(), json);
        json
    };
    assert!(baseline.contains("\"fault\""));
    for threads in [2usize, 8] {
        let s = Session::with_threads(threads);
        assert_eq!(
            s.evaluate_device(&req).unwrap().to_json().to_string(),
            baseline,
            "faulty device summary diverged at {threads} engine threads"
        );
    }
}

#[test]
fn requests_are_conserved_under_every_policy_and_fault_mix() {
    let mixes: Vec<(&str, FaultPlan)> = vec![
        (
            "hangs",
            FaultPlan {
                faults: vec![
                    Fault::Hang { unit: 0, at: 50, cycles: 200 },
                    Fault::Hang { unit: 1, at: 300, cycles: 100 },
                ],
                seed: 3,
            },
        ),
        ("death", FaultPlan { faults: vec![Fault::Death { unit: 0, at: 200 }], seed: 3 }),
        (
            "straggler",
            FaultPlan {
                faults: vec![Fault::Straggler { unit: 1, from: 100, until: 900, factor: 3.0 }],
                seed: 3,
            },
        ),
        (
            // a seeded mixed bag; corruption events are dropped because
            // this test runs without a CorruptionLab
            "random",
            FaultPlan {
                faults: FaultPlan::random(33, 2, 2_000, 12)
                    .faults
                    .into_iter()
                    .filter(|f| !matches!(f, Fault::Corruption { .. }))
                    .collect(),
                seed: 33,
            },
        ),
    ];
    for policy in policies() {
        for (name, plan) in &mixes {
            let mut c = cfg(2, policy.clone(), 4.0, 600);
            c.faults = plan.clone();
            c.deadline = Some(400);
            c.retry = RetryPolicy { max_attempts: 3, ..RetryPolicy::default() };
            c.shed = ShedPolicy::RejectNew { min_live: 2, max_depth: 32 };
            let (s, records) = run_card_faulty_traced(&c, &mut profile(), None).unwrap();
            let f = s.fault.as_ref().expect("faulty run must carry a fault summary");
            let label = format!("{} / {name}", s.policy);
            assert_eq!(f.offered, 600, "{label}: offered");
            assert_eq!(
                f.completed + f.timed_out + f.dropped(),
                f.offered,
                "{label}: conservation"
            );
            assert_eq!(s.requests, f.completed, "{label}: summary counts completions");
            assert_eq!(records.len(), f.completed, "{label}: one record per completion");
            assert_eq!(
                s.per_unit.iter().map(|u| u.requests).sum::<usize>(),
                f.completed,
                "{label}: per-unit accounting"
            );
            for r in &records {
                assert!(r.arrival <= r.start && r.start < r.done, "{label}: causality");
                assert!(
                    (1..=3).contains(&r.attempts),
                    "{label}: request {} took {} attempts",
                    r.id,
                    r.attempts
                );
            }
        }
    }
}

#[test]
fn deadlines_expire_requests_stuck_behind_a_hang() {
    let mut c = cfg(1, PolicyKind::LeastLoaded, 5.0, 400);
    c.faults =
        FaultPlan { faults: vec![Fault::Hang { unit: 0, at: 30, cycles: 600 }], seed: 1 };
    c.deadline = Some(100);
    let s = run_card_faulty(&c, &mut profile(), None).unwrap();
    let f = s.fault.as_ref().unwrap();
    assert_eq!(f.hangs, 1);
    assert!(
        f.timed_out > 0,
        "requests queued behind a 600-cycle hang must blow a 100-cycle deadline"
    );
    assert!(f.completed > 0, "the card must still serve after the thaw");
    assert_eq!(f.completed + f.timed_out + f.dropped(), f.offered);
}

#[test]
fn load_shedding_kicks_in_during_a_brownout() {
    let brownout = |shed: ShedPolicy| {
        // one of two units dies early under heavy traffic: the survivor
        // cannot keep up, so the watermark policy must start shedding
        let mut c = cfg(2, PolicyKind::LeastLoaded, 2.0, 400);
        c.faults = FaultPlan { faults: vec![Fault::Death { unit: 0, at: 100 }], seed: 2 };
        c.shed = shed;
        run_card_faulty(&c, &mut profile(), None).unwrap()
    };
    let reject = brownout(ShedPolicy::RejectNew { min_live: 2, max_depth: 8 });
    let fr = reject.fault.as_ref().unwrap();
    assert!(fr.shed_rejected > 0, "reject-new never fired");
    assert_eq!(fr.shed_dropped, 0, "reject-new must not evict waiters");
    assert_eq!(fr.completed + fr.timed_out + fr.dropped(), fr.offered);

    let drop_old = brownout(ShedPolicy::DropOldest { min_live: 2, max_depth: 8 });
    let fd = drop_old.fault.as_ref().unwrap();
    assert!(fd.shed_dropped > 0, "drop-oldest never fired");
    assert_eq!(fd.completed + fd.timed_out + fd.dropped(), fd.offered);
}

#[test]
fn the_watchdog_quarantines_a_straggler_and_probations_it_back() {
    let mut c = cfg(2, PolicyKind::RoundRobin, 4.0, 600);
    // factor 3 on a x2 watchdog: every block on unit 0 is a strike
    c.faults = FaultPlan {
        faults: vec![Fault::Straggler { unit: 0, from: 0, until: 100_000, factor: 3.0 }],
        seed: 4,
    };
    c.health = HealthPolicy {
        strike_threshold: 2,
        watchdog_factor: 2.0,
        quarantine_cycles: 250,
        probation_successes: 1,
    };
    c.retry = RetryPolicy { max_attempts: 3, ..RetryPolicy::default() };
    let s = run_card_faulty(&c, &mut profile(), None).unwrap();
    let f = s.fault.as_ref().unwrap();
    assert_eq!(f.stragglers, 1);
    assert!(f.strikes >= 2, "slow completions must accumulate strikes, got {}", f.strikes);
    assert!(f.quarantines >= 1, "two strikes must quarantine the unit");
    let timeline = &f.health[0].timeline;
    assert!(
        timeline.iter().any(|p| p.state == "quarantined"),
        "unit 0 timeline records no quarantine: {timeline:?}"
    );
    assert!(
        timeline.iter().any(|p| p.state == "probation"),
        "unit 0 never re-entered on probation: {timeline:?}"
    );
    assert_eq!(f.completed + f.timed_out + f.dropped(), f.offered);
}

#[test]
fn checked_dispatch_catches_corruption_that_unchecked_service_misses() {
    let session = Session::serial();
    let base = || {
        let mut r = DeviceRequest::point(point(), 2);
        r.card.seed = 5;
        r.card.requests = 80;
        r.card.arrival = ArrivalProcess::Poisson { mean_gap: 20.0 };
        r.with_faults(FaultPlan {
            faults: vec![Fault::Corruption { unit: 0, at: 40, flips: 32 }],
            seed: 77,
        })
    };

    // unchecked: the corrupted unit keeps serving, silently
    let silent = session.evaluate_device(&base()).unwrap();
    let fs = silent.fault.as_ref().unwrap();
    assert_eq!(fs.corruptions, 1);
    assert_eq!(fs.detected, 0, "nothing checks, nothing detects");
    assert!(fs.silent_served > 0, "the corrupted unit must have served requests");
    assert_eq!(fs.completed, fs.offered, "unchecked service completes everything");

    // checked dispatch: the DMR probe flags the unit and quarantines it
    let checked = session
        .evaluate_device(
            &base()
                .with_checked_dispatch()
                .with_retries(RetryPolicy { max_attempts: 4, ..RetryPolicy::default() }),
        )
        .unwrap();
    let fc = checked.fault.as_ref().unwrap();
    assert_eq!(fc.corruptions, 1);
    assert!(fc.detected >= 1, "the probe must catch a 32-bit weight corruption");
    assert_eq!(fc.silent_served, 0, "checked mode may not serve corrupted results");
    assert!(fc.quarantines >= 1, "detection must quarantine the unit");
    assert_eq!(fc.completed + fc.timed_out + fc.dropped(), fc.offered);
}

#[test]
fn the_fault_dsl_parses_and_rejects_as_documented() {
    let plan =
        FaultPlan::parse("hang:0@100+50, die:1@200, slow:0@10..90*2.5, flip:1@50*3", 9, 2, 1_000)
            .unwrap();
    assert_eq!(plan.seed, 9);
    assert_eq!(
        plan.faults,
        vec![
            Fault::Hang { unit: 0, at: 100, cycles: 50 },
            Fault::Death { unit: 1, at: 200 },
            Fault::Straggler { unit: 0, from: 10, until: 90, factor: 2.5 },
            Fault::Corruption { unit: 1, at: 50, flips: 3 },
        ]
    );

    // rand:N expands to the seeded random plan, appended in order
    let expanded = FaultPlan::parse("die:0@5, rand:4", 3, 4, 2_000).unwrap();
    assert_eq!(expanded.faults[0], Fault::Death { unit: 0, at: 5 });
    assert_eq!(&expanded.faults[1..], &FaultPlan::random(3, 4, 2_000, 4).faults[..]);

    for bad in [
        "boom:1@2",        // unknown kind
        "die:9@1",         // unit off the card
        "slow:0@90..10*2", // empty straggle window
        "hang:0@5+0",      // zero-cycle hang
        "die:1",           // missing @cycle
        "flip:0@5*0",      // zero flips
    ] {
        assert!(FaultPlan::parse(bad, 1, 2, 100).is_err(), "{bad:?} must be rejected");
    }
}
