//! Properties of the simulated accelerator card (DESIGN.md §Device
//! subsystem), across every scheduler policy:
//!
//!   * request conservation — every arrival completes exactly once, no
//!     drops, no duplicates, and service within a unit is FIFO;
//!   * sane accounting — arrival <= start <= done per request, per-unit
//!     utilization in [0, 1];
//!   * byte-determinism — the full `DeviceSummary` JSON is identical
//!     across repeated runs and across engine thread counts {1, 2, 8}
//!     (calibration fans out over the thread pool; the event loop itself
//!     is single-threaded virtual time);
//!   * the scheduling regression the subsystem exists to show: under
//!     saturation, the batch-aware policy (B=32) must beat round-robin
//!     on aggregate throughput by amortizing the pipeline fill.
//!
//! Run in CI under `--release` alongside the kernel-identity suites.

use finn_mvu::cfg::{DesignPoint, ValidatedParams};
use finn_mvu::device::{ArrivalProcess, PolicyKind};
use finn_mvu::eval::{DeviceRequest, Session};

/// A cheap fc MVU (16x8, PE 4, SIMD 8): 4b + 5 exec cycles for a block
/// of b vectors, so batching has a measurable win and calibration stays
/// fast even at B=32.
fn point() -> ValidatedParams {
    DesignPoint::fc("prop").in_features(16).out_features(8).pe(4).simd(8).build().unwrap()
}

fn policies() -> Vec<PolicyKind> {
    vec![
        PolicyKind::RoundRobin,
        PolicyKind::LeastLoaded,
        PolicyKind::BatchAware { block: 8, max_wait: 64 },
    ]
}

#[test]
fn requests_are_conserved_and_fifo_within_each_unit() {
    let session = Session::serial();
    for (ai, arrival) in [
        ArrivalProcess::Poisson { mean_gap: 6.0 },
        ArrivalProcess::Bursty { fast_gap: 2.0, slow_gap: 20.0, mean_run: 16.0 },
        ArrivalProcess::Diurnal { mean_gap: 6.0, swing: 0.8, period: 400.0 },
    ]
    .into_iter()
    .enumerate()
    {
        for policy in policies() {
            let mut req = DeviceRequest::point(point(), 2);
            req.card.policy = policy;
            req.card.arrival = arrival.clone();
            req.card.seed = 11 + ai as u64;
            req.card.requests = 400;
            let (summary, mut records) = session.evaluate_device_traced(&req).unwrap();
            let label = format!("{} / {}", summary.policy, summary.arrival);

            // conservation: ids 0..n, each exactly once
            assert_eq!(records.len(), 400, "{label}: dropped/duplicated requests");
            assert_eq!(summary.requests, 400, "{label}: summary request count");
            let mut ids: Vec<u64> = records.iter().map(|r| r.id).collect();
            ids.sort_unstable();
            assert_eq!(ids, (0..400).collect::<Vec<u64>>(), "{label}: id set");

            // per-request causality
            for r in &records {
                assert!(r.arrival <= r.start, "{label}: request {} started early", r.id);
                assert!(r.start < r.done, "{label}: request {} zero service", r.id);
            }

            // FIFO within a unit: in start order, ids stay ascending
            records.sort_by_key(|r| (r.unit, r.start, r.id));
            for pair in records.windows(2) {
                if pair[0].unit == pair[1].unit {
                    assert!(
                        pair[0].id < pair[1].id,
                        "{label}: unit {} served {} before {}",
                        pair[0].unit,
                        pair[1].id,
                        pair[0].id
                    );
                }
            }

            // accounting sanity
            let served: usize = summary.per_unit.iter().map(|u| u.requests).sum();
            assert_eq!(served, 400, "{label}: per-unit request counts");
            for u in &summary.per_unit {
                assert!(
                    (0.0..=1.0).contains(&u.utilization),
                    "{label}: unit {} utilization {} outside [0, 1]",
                    u.unit,
                    u.utilization
                );
            }
        }
    }
}

#[test]
fn summaries_are_byte_identical_across_runs_and_thread_counts() {
    // the acceptance scenario shape: a 4-unit NID-chain card, with a
    // batch-aware policy so calibration really fans out over the pool
    let req = {
        let mut r = DeviceRequest::nid(4);
        r.card.policy = PolicyKind::BatchAware { block: 4, max_wait: 128 };
        r.card.seed = 7;
        r.card.requests = 1200;
        r.card.trace_every = 500;
        r
    };
    let baseline = {
        let s = Session::with_threads(1);
        let json = s.evaluate_device(&req).unwrap().to_json().to_string();
        // same session, second run: served from the result cache, same bytes
        assert_eq!(s.evaluate_device(&req).unwrap().to_json().to_string(), json);
        json
    };
    for threads in [2usize, 8] {
        let s = Session::with_threads(threads);
        assert_eq!(
            s.evaluate_device(&req).unwrap().to_json().to_string(),
            baseline,
            "device summary diverged at {threads} engine threads"
        );
    }
}

#[test]
fn batch_aware_beats_round_robin_at_saturation() {
    // arrivals at 1 per 2 cycles against 4 units serving 4b + 5 cycles
    // per block: round-robin (b = 1) offers 4/9 < 1/2 requests per cycle
    // and saturates, while B=32 blocks amortize the fill to ~4.16
    // cycles/request and keep up
    let session = Session::serial();
    let run = |policy: PolicyKind| {
        let mut req = DeviceRequest::point(point(), 4);
        req.card.policy = policy;
        req.card.arrival = ArrivalProcess::Poisson { mean_gap: 2.0 };
        req.card.seed = 3;
        req.card.requests = 4000;
        session.evaluate_device(&req).unwrap()
    };
    let rr = run(PolicyKind::RoundRobin);
    let batch = run(PolicyKind::BatchAware { block: 32, max_wait: 256 });
    assert!(
        batch.throughput_rpkc > rr.throughput_rpkc,
        "batch-aware ({} req/kcycle) must beat round-robin ({} req/kcycle) at saturation",
        batch.throughput_rpkc,
        rr.throughput_rpkc
    );
    assert!(
        batch.mean_occupancy > 4.0,
        "batch-aware card under overload should fill blocks (occupancy {})",
        batch.mean_occupancy
    );
    // and the saturated round-robin card should be pegged
    for u in &rr.per_unit {
        assert!(u.utilization > 0.9, "saturated rr unit {} at {}", u.unit, u.utilization);
    }
}
