//! Full-stack integration over the AOT artifacts: the three backends
//! (cycle-accurate simulator, PJRT executables, reference GEMM) must agree
//! bit-exactly on the same weights — the software analogue of the paper's
//! board validation (§6.1). Skipped gracefully when `make artifacts` has
//! not run.

use finn_mvu::cfg::SimdType;
use finn_mvu::coordinator::{Pipeline, PipelineConfig, Request};
use finn_mvu::nid::{generate, NidNetwork};
use finn_mvu::quant::{matvec, multithreshold};
use finn_mvu::runtime::{default_artifacts_dir, Engine, Manifest};
use finn_mvu::sim::{run_mvu, SlidingWindowUnit};
use finn_mvu::util::rng::Pcg32;

fn engine() -> Option<Engine> {
    let dir = default_artifacts_dir();
    dir.join("manifest.json").exists().then(|| Engine::new(&dir).unwrap())
}

#[test]
fn generic_mvu_three_way_agreement() {
    let Some(e) = engine() else { return };
    let gw = e.manifest.generic_weights().unwrap();
    for (name, ty) in [
        ("mvu_xnor", SimdType::Xnor),
        ("mvu_binary", SimdType::BinaryWeights),
        ("mvu_standard", SimdType::Standard),
    ] {
        let kernel = e.load(&format!("{name}_b1")).unwrap();
        let params = kernel.info.layer.clone().unwrap();
        let w = &gw[name];
        let mut rng = Pcg32::new(500);
        let x: Vec<i32> = (0..w.cols)
            .map(|_| match ty {
                SimdType::Xnor => rng.next_range(2) as i32,
                _ => rng.next_range(16) as i32 - 8,
            })
            .collect();
        let want = matvec(&x, w, ty).unwrap();
        let pjrt = kernel.run(&x).unwrap();
        let sim = run_mvu(&params, w, &[x.clone()]).unwrap();
        assert_eq!(pjrt, want, "{name}: PJRT vs ref");
        assert_eq!(sim.outputs[0], want, "{name}: sim vs ref");
    }
}

#[test]
fn batched_artifacts_agree_rowwise() {
    let Some(e) = engine() else { return };
    let k1 = e.load("mvu_standard_b1").unwrap();
    let k16 = e.load("mvu_standard_b16").unwrap();
    let cols = k1.info.in_shape[1];
    let mut rng = Pcg32::new(501);
    let rows: Vec<Vec<i32>> = (0..16)
        .map(|_| (0..cols).map(|_| rng.next_range(16) as i32 - 8).collect())
        .collect();
    let flat: Vec<i32> = rows.concat();
    let out16 = k16.run(&flat).unwrap();
    let out_cols = k1.info.out_shape[1];
    for (i, row) in rows.iter().enumerate() {
        let out1 = k1.run(row).unwrap();
        assert_eq!(out1, out16[i * out_cols..(i + 1) * out_cols], "row {i}");
    }
}

#[test]
fn conv_artifact_matches_swu_plus_sim() {
    let Some(e) = engine() else { return };
    let kernel = e.load("conv3x3_b1").unwrap();
    let params = kernel.info.layer.clone().unwrap();
    let w = &e.manifest.generic_weights().unwrap()["conv3x3"];
    let mut rng = Pcg32::new(502);
    let img: Vec<i32> = (0..params.ifm_dim * params.ifm_dim * params.ifm_ch)
        .map(|_| rng.next_range(16) as i32 - 8)
        .collect();
    let pjrt = kernel.run(&img).unwrap();
    let swu =
        SlidingWindowUnit::new(params.ifm_dim, params.ifm_dim, params.ifm_ch, params.kernel_dim, 1)
            .unwrap();
    let vectors = swu.expand(&img).unwrap();
    let sim = run_mvu(&params, w, &vectors).unwrap();
    assert_eq!(sim.outputs.concat(), pjrt);
}

#[test]
fn nid_pipeline_sim_and_reference_agree_and_classify() {
    let Some(e) = engine() else { return };
    let manifest = Manifest::load(&default_artifacts_dir()).unwrap();
    let net = NidNetwork::load(&manifest).unwrap();
    let records = generate(64, 31337);

    // pipeline over PJRT
    let reqs: Vec<Request> = records
        .iter()
        .enumerate()
        .map(|(i, r)| Request { id: i as u64, data: r.inputs.clone() })
        .collect();
    let cfg = PipelineConfig { batch: 16, ..Default::default() };
    let pipe = Pipeline::nid(default_artifacts_dir(), cfg);
    let (mut resp, _) = pipe.run(reqs).unwrap();
    resp.sort_by_key(|r| r.id);

    // cycle-accurate simulation of all four layers, per record
    let weights = manifest.nid_weights().unwrap();
    let layers = finn_mvu::cfg::nid_layers();
    let mut correct = 0usize;
    for (i, rec) in records.iter().enumerate() {
        let mut v = rec.inputs.clone();
        for (params, (w, th)) in layers.iter().zip(&weights) {
            let acc = run_mvu(params, w, &[v]).unwrap().outputs[0].clone();
            v = match th {
                Some(t) => multithreshold(&acc, t).unwrap(),
                None => acc,
            };
        }
        let want = net.forward(&rec.inputs).unwrap();
        assert_eq!(v, want, "sim vs reference at record {i}");
        assert_eq!(resp[i].output, want, "pipeline vs reference at record {i}");
        if net.decide(want[0]) == rec.label {
            correct += 1;
        }
    }
    let acc = correct as f64 / records.len() as f64;
    assert!(acc > 0.70, "classification accuracy {acc}");
}

#[test]
fn fused_network_equals_layer_chain() {
    let Some(e) = engine() else { return };
    let fused = e.load("nid_fused_b1").unwrap();
    let net = NidNetwork::load(&e.manifest).unwrap();
    let records = generate(8, 41);
    for rec in &records {
        let out = fused.run(&rec.inputs).unwrap();
        assert_eq!(out, net.forward(&rec.inputs).unwrap());
    }
}

#[test]
fn engine_cache_shared_across_loads() {
    let Some(e) = engine() else { return };
    let a = e.load("nid_layer1_b1").unwrap();
    let b = e.load("nid_layer1_b1").unwrap();
    assert!(std::sync::Arc::ptr_eq(&a, &b));
}
