//! Acceptance tests for the unified evaluation facade:
//!
//! * the `DesignPoint` builder accepts exactly the parameter sets
//!   `LayerParams::validate` accepts, and returns the matching
//!   `ParamError` variant on each illegal axis;
//! * `Session::evaluate` output is bit-identical to the underlying
//!   `run_mvu` + `estimate` primitives for the full Table 2 grid.

use finn_mvu::cfg::{
    DesignPoint, FoldAxis, LayerParams, ParamError, SimdType, ValidatedParams,
};
use finn_mvu::estimate::{estimate, Style};
use finn_mvu::eval::{ChainRequest, EvalError, EvalRequest, Session, SessionConfig, SimOptions};
use finn_mvu::explore::{
    content_hash, estimate_key, params_key, stimulus_inputs, stimulus_seed, stimulus_weights,
};
use finn_mvu::harness::SweepKind;
use finn_mvu::proptest::{check, Config, Gen};
use finn_mvu::sim::{run_mvu, StallPattern};
use finn_mvu::util::json::Json;

/// A raw parameter record over a range that covers every legality axis:
/// zero dims, non-divisor folds, oversized kernels, precision clashes.
fn arb_raw_params(g: &mut Gen) -> LayerParams {
    LayerParams {
        name: "raw".to_string(),
        ifm_ch: g.usize_in(0, 20),
        ifm_dim: g.usize_in(0, 6),
        ofm_ch: g.usize_in(0, 20),
        kernel_dim: g.usize_in(0, 6),
        pe: g.usize_in(0, 8),
        simd: g.usize_in(0, 8),
        simd_type: *g.choose(&SimdType::ALL),
        weight_bits: g.usize_in(1, 4) as u32,
        input_bits: g.usize_in(1, 4) as u32,
        output_bits: g.usize_in(0, 2) as u32,
    }
}

/// The builder is a front door over `validate()`: `from_params(p).build()`
/// must accept exactly the `p` that `p.validate()` accepts and return the
/// identical structured error otherwise.
#[test]
fn prop_builder_accepts_exactly_what_validate_accepts() {
    check("builder==validate", Config::cases(300), |g| {
        let p = arb_raw_params(g);
        let direct = p.validate();
        let built = DesignPoint::from_params(p.clone()).build();
        match (direct, built) {
            (Ok(()), Ok(vp)) => {
                if vp.params() != &p {
                    return Err(format!("builder altered the params for {p}"));
                }
                Ok(())
            }
            (Err(a), Err(b)) => {
                if a != b {
                    return Err(format!("error mismatch for {p}: {a:?} vs {b:?}"));
                }
                Ok(())
            }
            (a, b) => Err(format!("accept/reject disagree for {p}: {a:?} vs {b:?}")),
        }
    });
}

/// Each illegal axis yields its own `ParamError` variant, with the axis
/// details filled in.
#[test]
fn prop_error_variant_matches_the_illegal_axis() {
    check("error-variants", Config::cases(200), |g| {
        // start from a legal base and break exactly one axis
        let base = DesignPoint::fc("v")
            .in_features(12)
            .out_features(6)
            .pe(*g.choose(&[1usize, 2, 3, 6]))
            .simd(*g.choose(&[1usize, 2, 3, 4, 6, 12]))
            .build()
            .map_err(|e| e.to_string())?
            .into_inner();
        let axis = g.usize_in(0, 3);
        let mut p = base;
        match axis {
            0 => p.simd = 5,                       // not a divisor of 12
            1 => p.pe = 4,                         // not a divisor of 6
            2 => p.kernel_dim = 3,                 // larger than ifm_dim = 1
            _ => p.simd_type = SimdType::Xnor,     // 4-bit operands under xnor
        }
        let err = match p.clone().validated() {
            Err(e) => e,
            Ok(_) => return Err(format!("axis {axis} should be illegal for {p}")),
        };
        let matches_axis = match axis {
            0 => matches!(
                err,
                ParamError::IllegalFold { axis: FoldAxis::Simd, value: 5, .. }
            ),
            1 => matches!(err, ParamError::IllegalFold { axis: FoldAxis::Pe, value: 4, .. }),
            // breaking the kernel can also break SIMD divisibility first;
            // both are fold/geometry errors, never precision
            2 => matches!(
                err,
                ParamError::KernelExceedsIfm { .. } | ParamError::IllegalFold { .. }
            ),
            _ => matches!(err, ParamError::PrecisionRule { simd_type: SimdType::Xnor, .. }),
        };
        if !matches_axis {
            return Err(format!("axis {axis}: unexpected variant {err:?}"));
        }
        Ok(())
    });
}

/// The facade is a zero-cost front: for every Table 2 point (all six
/// sweeps, all three SIMD types), `Session::evaluate` must be
/// bit-identical to calling the `estimate` and `run_mvu` primitives
/// directly with the engine's canonical stimulus.
#[test]
fn session_bit_identical_to_primitives_on_table2_grid() {
    let session = Session::parallel();
    let vectors = 2usize;
    for kind in SweepKind::ALL {
        for ty in SimdType::ALL {
            for sp in kind.points(ty) {
                let req = EvalRequest::new(sp.params.clone())
                    .with_sim(SimOptions { batch: vectors, ..SimOptions::default() });
                let ev = session.evaluate(&req).unwrap();

                // estimates: field-for-field identical (f64 compared by
                // bits via ==; both sides run the same pure function)
                for style in [Style::Rtl, Style::Hls] {
                    let direct = estimate(&sp.params, style);
                    let got = ev.estimate_for(style).unwrap();
                    assert_eq!(got.luts, direct.luts, "{} {style:?}", sp.params);
                    assert_eq!(got.ffs, direct.ffs, "{} {style:?}", sp.params);
                    assert_eq!(got.bram18, direct.bram18, "{} {style:?}", sp.params);
                    assert_eq!(got.delay_ns, direct.delay_ns, "{} {style:?}", sp.params);
                    assert_eq!(
                        got.synth_time_s, direct.synth_time_s,
                        "{} {style:?}",
                        sp.params
                    );
                    assert_eq!(
                        got.delay_location,
                        direct.delay_location.name(),
                        "{} {style:?}",
                        sp.params
                    );
                }

                // simulation: same canonical stimulus (fold-independent
                // seed since kernel version 3), same report
                let seed = stimulus_seed(&sp.params);
                let weights = stimulus_weights(&sp.params, seed);
                let inputs =
                    stimulus_inputs(&sp.params, seed ^ 0x9e37_79b9_7f4a_7c15, vectors);
                let direct = run_mvu(&sp.params, &weights, &inputs).unwrap();
                let sim = ev.sim.as_ref().unwrap();
                assert!(sim.matches_reference, "{}", sp.params);
                assert_eq!(sim.exec_cycles, direct.exec_cycles, "{}", sp.params);
                assert_eq!(sim.stall_cycles, direct.stall_cycles, "{}", sp.params);
                assert_eq!(sim.slots_consumed, direct.slots_consumed, "{}", sp.params);
                assert_eq!(
                    sim.fifo_max_occupancy, direct.fifo_max_occupancy,
                    "{}",
                    sp.params
                );
            }
        }
    }
}

/// `ValidatedParams` is the only door: a point that round-trips through
/// the `LayerParams` exit hatch must re-validate before the compute
/// layers accept it, and the sealed value equals the original.
#[test]
fn validated_params_roundtrip_preserves_identity() {
    let vp = DesignPoint::fc("rt")
        .in_features(48)
        .out_features(16)
        .pe(4)
        .simd(6)
        .precision(2, 2, 0)
        .build()
        .unwrap();
    let raw: LayerParams = vp.clone().into_inner();
    let back: ValidatedParams = raw.validated().unwrap();
    assert_eq!(back, vp);
    assert_eq!(params_key(&back), params_key(&vp));
}

fn small_point(name: &str) -> ValidatedParams {
    DesignPoint::fc(name).in_features(16).out_features(8).pe(4).simd(8).build().unwrap()
}

/// A stall pattern under which the MVU can never deliver an output word.
fn never_ready() -> StallPattern {
    StallPattern::Periodic { period: 1, duty: 1, phase: 0 }
}

/// `evaluate_all` must report the *smallest* failing request index
/// structurally, independent of thread count, with the request's own
/// error chain in the message.
#[test]
fn evaluate_all_reports_first_failing_index() {
    let dead = SimOptions { batch: 1, out_stall: never_ready(), ..SimOptions::default() };
    let mut reqs: Vec<EvalRequest> =
        (0..6).map(|i| EvalRequest::new(small_point(&format!("ok{i}")))).collect();
    reqs[2] = EvalRequest::new(small_point("dead2")).with_sim(dead.clone());
    reqs[4] = EvalRequest::new(small_point("dead4")).with_sim(dead);
    for threads in [1usize, 4] {
        let session = Session::with_threads(threads);
        match session.evaluate_all(&reqs) {
            Err(EvalError::Sweep { index, message }) => {
                assert_eq!(index, 2, "threads={threads}: smallest failing index wins");
                assert!(message.contains("request 2"), "{message}");
                assert!(message.contains("deadlock"), "{message}");
            }
            other => panic!("threads={threads}: expected EvalError::Sweep, got {other:?}"),
        }
    }
}

/// `evaluate_layers` (over `try_evaluate_points`) carries the failing
/// sweep index structurally. The only way a validated point can fail
/// estimation is a corrupted cache entry, so poison one on disk.
#[test]
fn evaluate_layers_reports_failing_sweep_index_from_poisoned_cache() {
    let dir = std::env::temp_dir().join(format!("finn-mvu-evalapi-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let layers: Vec<ValidatedParams> = (0..4)
        .map(|i| {
            DesignPoint::fc(&format!("l{i}"))
                .in_features(8 << i)
                .out_features(8)
                .pe(2)
                .simd(4)
                .build()
                .unwrap()
        })
        .collect();
    // a key-valid envelope whose value is not a StyleReport
    let key = estimate_key(&layers[2], Style::Rtl);
    let mut doc = Json::obj();
    doc.set("key", Json::Str(key.clone()));
    doc.set("value", Json::obj());
    let path = dir.join(format!("{:016x}.json", content_hash(&key)));
    std::fs::write(&path, doc.to_string()).unwrap();

    let session = Session::new(SessionConfig {
        threads: 1,
        sim_vectors: 0,
        cache_dir: Some(dir.clone()),
    })
    .unwrap();
    match session.evaluate_layers(&layers) {
        Err(EvalError::Sweep { index, message }) => {
            assert_eq!(index, 2, "{message}");
            assert!(message.contains("sweep point 2"), "{message}");
        }
        other => panic!("expected EvalError::Sweep at index 2, got {other:?}"),
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Changing any `SimOptions` field that affects the modelled flow
/// (FIFO depth, stall patterns) must land in a fresh cache entry — and
/// repeating an identical request must not.
#[test]
fn sim_options_changes_invalidate_cache_entries() {
    let s = Session::serial();
    let base = || EvalRequest::new(small_point("c"));
    s.evaluate(&base().with_sim(SimOptions { batch: 2, ..SimOptions::default() })).unwrap();
    let m0 = s.cache_stats().misses;

    // identical request: served entirely from cache
    s.evaluate(&base().with_sim(SimOptions { batch: 2, ..SimOptions::default() })).unwrap();
    assert_eq!(s.cache_stats().misses, m0, "identical SimOptions must hit");

    // different FIFO depth: new simulation entry
    s.evaluate(&base().with_sim(SimOptions { batch: 2, fifo_depth: 2, ..SimOptions::default() }))
        .unwrap();
    let m1 = s.cache_stats().misses;
    assert!(m1 > m0, "fifo_depth change must miss: {:?}", s.cache_stats());

    // different stall pattern: yet another entry
    s.evaluate(&base().with_sim(SimOptions {
        batch: 2,
        in_stall: StallPattern::Periodic { period: 4, duty: 1, phase: 0 },
        ..SimOptions::default()
    }))
    .unwrap();
    assert!(s.cache_stats().misses > m1, "stall change must miss: {:?}", s.cache_stats());
}

/// Chain evaluations through the facade: deterministic across sessions
/// (same canonical stimulus), kernel-verified against the layer-wise
/// reference, and cache-keyed on the flow like single-point simulations.
#[test]
fn evaluate_chain_is_deterministic_and_flow_keyed() {
    let req = ChainRequest::nid().with_sim(SimOptions { batch: 2, ..SimOptions::default() });
    let a = Session::serial().evaluate_chain(&req).unwrap();
    let b = Session::serial().evaluate_chain(&req).unwrap();
    assert_eq!(a, b, "fresh sessions must produce identical chain summaries");
    assert!(a.matches_reference);
    assert!(a.first_out_cycle < a.exec_cycles);
    // steady state: one output vector per bottleneck II once filled
    assert!(a.exec_cycles >= a.bottleneck_ii * 2);

    let s = Session::serial();
    s.evaluate_chain(&req).unwrap();
    let m0 = s.cache_stats().misses;
    s.evaluate_chain(&req).unwrap();
    assert_eq!(s.cache_stats().misses, m0, "identical chain request must hit");
    let stalled = req.clone().with_sim(SimOptions {
        batch: 2,
        out_stall: StallPattern::Periodic { period: 6, duty: 2, phase: 0 },
        ..SimOptions::default()
    });
    s.evaluate_chain(&stalled).unwrap();
    assert!(s.cache_stats().misses > m0, "flow change must miss: {:?}", s.cache_stats());
}
