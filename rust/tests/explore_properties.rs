//! Property tests over the design-space exploration engine (own proptest
//! framework): parallel evaluation must be byte-identical and identically
//! ordered to serial evaluation for every thread count, and cache hits —
//! memory or disk — must return bit-identical reports.

use finn_mvu::cfg::{LayerParams, SimdType, SweepPoint};
use finn_mvu::explore::{points_to_json, ExploreConfig, Explorer};
use finn_mvu::harness::SweepKind;
use finn_mvu::proptest::{check, Config, Gen};

/// Random mix of real Table 2 sweep points and synthetic FC points, with
/// duplicates allowed (duplicates exercise the cache sharing path).
fn arb_points(g: &mut Gen) -> Vec<SweepPoint> {
    let mut pool: Vec<SweepPoint> = Vec::new();
    let kind = *g.choose(&SweepKind::ALL);
    let ty = *g.choose(&SimdType::ALL);
    pool.extend(kind.points(ty));
    for i in 0..g.usize_in(1, 4) {
        let ty = *g.choose(&SimdType::ALL);
        let (wb, ib) = match ty {
            SimdType::Xnor => (1, 1),
            SimdType::BinaryWeights => (1, *g.choose(&[2u32, 4])),
            SimdType::Standard => (*g.choose(&[2u32, 4]), *g.choose(&[2u32, 4])),
        };
        let rows = g.usize_in(1, 16);
        let cols = g.usize_in(1, 48);
        let pe = g.divisor_of(rows);
        let simd = g.divisor_of(cols);
        pool.push(SweepPoint {
            swept: i,
            params: LayerParams::fc(&format!("fc{i}"), cols, rows, pe, simd, ty, wb, ib, 0),
        });
    }
    // random subset with repetition
    (0..g.usize_in(1, 10)).map(|_| g.choose(&pool).clone()).collect()
}

/// Tentpole acceptance property: for random sweeps, thread counts 1, 2
/// and 8 produce identical, identically-ordered results — byte-identical
/// once serialized.
#[test]
fn prop_parallel_identical_and_ordered_vs_serial() {
    check("explore-parallel==serial", Config::cases(20), |g| {
        let points = arb_points(g);
        let serial = Explorer::serial().evaluate_points(&points).map_err(|e| e.to_string())?;
        if serial.len() != points.len() {
            return Err("result count mismatch".into());
        }
        for (sp, r) in points.iter().zip(&serial) {
            if r.name != sp.params.name || r.swept != sp.swept {
                return Err(format!("order broken: {} vs {}", r.name, sp.params.name));
            }
        }
        let serial_bytes = points_to_json(&serial).to_string();
        for threads in [2usize, 8] {
            let par = Explorer::with_threads(threads)
                .evaluate_points(&points)
                .map_err(|e| e.to_string())?;
            if par != serial {
                return Err(format!("threads={threads}: reports differ from serial"));
            }
            if points_to_json(&par).to_string() != serial_bytes {
                return Err(format!("threads={threads}: serialized bytes differ"));
            }
        }
        Ok(())
    });
}

/// Same determinism with the cycle-accurate simulator enabled (small
/// synthetic points only, to keep the property fast).
#[test]
fn prop_parallel_identical_with_simulation() {
    check("explore-sim-parallel==serial", Config::cases(10), |g| {
        let mut points = Vec::new();
        for i in 0..g.usize_in(2, 5) {
            let rows = g.usize_in(1, 8);
            let cols = g.usize_in(1, 16);
            let pe = g.divisor_of(rows);
            let simd = g.divisor_of(cols);
            points.push(SweepPoint {
                swept: i,
                params: LayerParams::fc(
                    &format!("s{i}"),
                    cols,
                    rows,
                    pe,
                    simd,
                    SimdType::Standard,
                    2,
                    2,
                    0,
                ),
            });
        }
        let eval = |threads: usize| {
            Explorer::new(ExploreConfig { threads, sim_vectors: 2, cache_dir: None })
                .and_then(|ex| ex.evaluate_points(&points))
                .map_err(|e| e.to_string())
        };
        let serial = eval(1)?;
        for r in &serial {
            let sim = r.sim.as_ref().ok_or("sim summary missing")?;
            if !sim.matches_reference {
                return Err(format!("{}: sim diverged from reference", r.name));
            }
        }
        for threads in [2usize, 8] {
            if eval(threads)? != serial {
                return Err(format!("threads={threads}: sim reports differ"));
            }
        }
        Ok(())
    });
}

/// Cache property: re-evaluating the same sweep adds no misses, and the
/// reports served from cache are bit-identical to the first pass.
#[test]
fn prop_cache_hits_bit_identical() {
    check("explore-cache-hits", Config::cases(15), |g| {
        let points = arb_points(g);
        let threads = *g.choose(&[1usize, 2, 8]);
        let ex = Explorer::with_threads(threads);
        let first = ex.evaluate_points(&points).map_err(|e| e.to_string())?;
        let misses_after_first = ex.cache_stats().misses;
        let second = ex.evaluate_points(&points).map_err(|e| e.to_string())?;
        let stats = ex.cache_stats();
        if stats.misses != misses_after_first {
            return Err(format!(
                "second pass missed the cache: {misses_after_first} -> {}",
                stats.misses
            ));
        }
        if points_to_json(&second).to_string() != points_to_json(&first).to_string() {
            return Err("cache hit returned different bytes".into());
        }
        Ok(())
    });
}

/// The cache key excludes `LayerParams::name`: the same geometry under a
/// different label must be served from cache.
#[test]
fn cache_key_ignores_point_names() {
    let ex = Explorer::serial();
    let a = SweepPoint {
        swept: 64,
        params: LayerParams::conv("pe64", 64, 8, 64, 4, 64, 64, SimdType::Standard, 4, 4),
    };
    let mut renamed = a.clone();
    renamed.params.name = "simd64".to_string();
    let ra = ex.evaluate_points(&[a]).unwrap();
    let misses = ex.cache_stats().misses;
    let rb = ex.evaluate_points(&[renamed]).unwrap();
    assert_eq!(ex.cache_stats().misses, misses, "renamed geometry must hit the cache");
    assert_eq!(ra[0].rtl, rb[0].rtl);
    assert_eq!(ra[0].hls, rb[0].hls);
    assert_eq!(rb[0].name, "simd64"); // the label still reflects the input
}

/// On-disk cache: a second explorer over the same directory serves disk
/// hits that re-serialize to identical bytes, across thread counts.
#[test]
fn disk_cache_roundtrip_bit_identical() {
    let dir = std::env::temp_dir().join(format!("finn-mvu-explore-it-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let points = SweepKind::IfmChannels.points(SimdType::Standard);

    let cfg = |threads: usize| ExploreConfig {
        threads,
        sim_vectors: 1,
        cache_dir: Some(dir.clone()),
    };
    let first = Explorer::new(cfg(1)).unwrap().evaluate_points(&points).unwrap();
    let second_ex = Explorer::new(cfg(8)).unwrap();
    let second = second_ex.evaluate_points(&points).unwrap();
    let stats = second_ex.cache_stats();
    assert_eq!(stats.misses, 0, "fresh explorer must be served from disk: {stats:?}");
    assert!(stats.disk_hits > 0);
    assert_eq!(
        points_to_json(&first).to_string(),
        points_to_json(&second).to_string(),
        "disk-cached reports must be byte-identical"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Errors are deterministic too: an invalid point mixed into a sweep
/// yields the same error (the smallest failing index) at every thread
/// count.
#[test]
fn error_reporting_is_deterministic_across_thread_counts() {
    let mut points = SweepKind::Pe.points(SimdType::Standard);
    let mut bad = points[2].clone();
    bad.params.simd = 7; // does not divide K^2*IC = 1024
    bad.params.name = "illegal".to_string();
    points.insert(2, bad);
    let errs: Vec<String> = [1usize, 2, 8]
        .into_iter()
        .map(|t| {
            Explorer::with_threads(t)
                .evaluate_points(&points)
                .expect_err("invalid point must fail")
                .to_string()
        })
        .collect();
    assert!(errs[0].contains("sweep point 2"), "{}", errs[0]);
    assert_eq!(errs[0], errs[1]);
    assert_eq!(errs[1], errs[2]);
}
