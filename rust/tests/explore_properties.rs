//! Property tests over the design-space exploration engine (own proptest
//! framework): parallel evaluation must be byte-identical and identically
//! ordered to serial evaluation for every thread count, and cache hits —
//! memory or disk — must return bit-identical reports.

use finn_mvu::cfg::{DesignPoint, SimdType, SweepPoint};
use finn_mvu::explore::{points_to_json, ExploreConfig, Explorer};
use finn_mvu::harness::SweepKind;
use finn_mvu::proptest::{check, Config, Gen};

/// Random mix of real Table 2 sweep points and synthetic FC points, with
/// duplicates allowed (duplicates exercise the cache sharing path).
fn arb_points(g: &mut Gen) -> Vec<SweepPoint> {
    let mut pool: Vec<SweepPoint> = Vec::new();
    let kind = *g.choose(&SweepKind::ALL);
    let ty = *g.choose(&SimdType::ALL);
    pool.extend(kind.points(ty));
    for i in 0..g.usize_in(1, 4) {
        let ty = *g.choose(&SimdType::ALL);
        let (wb, ib) = match ty {
            SimdType::Xnor => (1, 1),
            SimdType::BinaryWeights => (1, *g.choose(&[2u32, 4])),
            SimdType::Standard => (*g.choose(&[2u32, 4]), *g.choose(&[2u32, 4])),
        };
        let rows = g.usize_in(1, 16);
        let cols = g.usize_in(1, 48);
        let pe = g.divisor_of(rows);
        let simd = g.divisor_of(cols);
        pool.push(SweepPoint {
            swept: i,
            params: DesignPoint::fc(&format!("fc{i}"))
                .in_features(cols)
                .out_features(rows)
                .pe(pe)
                .simd(simd)
                .simd_type(ty)
                .precision(wb, ib, 0)
                .build()
                .expect("generated folds are divisors, hence legal"),
        });
    }
    // random subset with repetition
    (0..g.usize_in(1, 10)).map(|_| g.choose(&pool).clone()).collect()
}

/// Tentpole acceptance property: for random sweeps, thread counts 1, 2
/// and 8 produce identical, identically-ordered results — byte-identical
/// once serialized.
#[test]
fn prop_parallel_identical_and_ordered_vs_serial() {
    check("explore-parallel==serial", Config::cases(20), |g| {
        let points = arb_points(g);
        let serial = Explorer::serial().evaluate_points(&points).map_err(|e| e.to_string())?;
        if serial.len() != points.len() {
            return Err("result count mismatch".into());
        }
        for (sp, r) in points.iter().zip(&serial) {
            if r.name != sp.params.name || r.swept != sp.swept {
                return Err(format!("order broken: {} vs {}", r.name, sp.params.name));
            }
        }
        let serial_bytes = points_to_json(&serial).to_string();
        for threads in [2usize, 8] {
            let par = Explorer::with_threads(threads)
                .evaluate_points(&points)
                .map_err(|e| e.to_string())?;
            if par != serial {
                return Err(format!("threads={threads}: reports differ from serial"));
            }
            if points_to_json(&par).to_string() != serial_bytes {
                return Err(format!("threads={threads}: serialized bytes differ"));
            }
        }
        Ok(())
    });
}

/// Same determinism with the cycle-accurate simulator enabled (small
/// synthetic points only, to keep the property fast).
#[test]
fn prop_parallel_identical_with_simulation() {
    check("explore-sim-parallel==serial", Config::cases(10), |g| {
        let mut points = Vec::new();
        for i in 0..g.usize_in(2, 5) {
            let rows = g.usize_in(1, 8);
            let cols = g.usize_in(1, 16);
            let pe = g.divisor_of(rows);
            let simd = g.divisor_of(cols);
            points.push(SweepPoint {
                swept: i,
                params: DesignPoint::fc(&format!("s{i}"))
                    .in_features(cols)
                    .out_features(rows)
                    .pe(pe)
                    .simd(simd)
                    .precision(2, 2, 0)
                    .build()
                    .expect("generated folds are divisors, hence legal"),
            });
        }
        let eval = |threads: usize| {
            Explorer::new(ExploreConfig { threads, sim_vectors: 2, cache_dir: None })
                .and_then(|ex| ex.evaluate_points(&points))
                .map_err(|e| e.to_string())
        };
        let serial = eval(1)?;
        for r in &serial {
            let sim = r.sim.as_ref().ok_or("sim summary missing")?;
            if !sim.matches_reference {
                return Err(format!("{}: sim diverged from reference", r.name));
            }
        }
        for threads in [2usize, 8] {
            if eval(threads)? != serial {
                return Err(format!("threads={threads}: sim reports differ"));
            }
        }
        Ok(())
    });
}

/// Cache property: re-evaluating the same sweep adds no misses, and the
/// reports served from cache are bit-identical to the first pass.
#[test]
fn prop_cache_hits_bit_identical() {
    check("explore-cache-hits", Config::cases(15), |g| {
        let points = arb_points(g);
        let threads = *g.choose(&[1usize, 2, 8]);
        let ex = Explorer::with_threads(threads);
        let first = ex.evaluate_points(&points).map_err(|e| e.to_string())?;
        let misses_after_first = ex.cache_stats().misses;
        let second = ex.evaluate_points(&points).map_err(|e| e.to_string())?;
        let stats = ex.cache_stats();
        if stats.misses != misses_after_first {
            return Err(format!(
                "second pass missed the cache: {misses_after_first} -> {}",
                stats.misses
            ));
        }
        if points_to_json(&second).to_string() != points_to_json(&first).to_string() {
            return Err("cache hit returned different bytes".into());
        }
        Ok(())
    });
}

/// The cache key excludes the point's display name: the same geometry
/// under a different label must be served from cache.
#[test]
fn cache_key_ignores_point_names() {
    let ex = Explorer::serial();
    let geometry = |name: &str| {
        DesignPoint::conv(name)
            .ifm_ch(64)
            .ifm_dim(8)
            .ofm_ch(64)
            .kernel_dim(4)
            .pe(64)
            .simd(64)
            .paper_precision(SimdType::Standard)
            .build()
            .unwrap()
    };
    let a = SweepPoint { swept: 64, params: geometry("pe64") };
    let renamed = SweepPoint { swept: 64, params: geometry("simd64") };
    let ra = ex.evaluate_points(&[a]).unwrap();
    let misses = ex.cache_stats().misses;
    let rb = ex.evaluate_points(&[renamed]).unwrap();
    assert_eq!(ex.cache_stats().misses, misses, "renamed geometry must hit the cache");
    assert_eq!(ra[0].rtl, rb[0].rtl);
    assert_eq!(ra[0].hls, rb[0].hls);
    assert_eq!(rb[0].name, "simd64"); // the label still reflects the input
}

/// On-disk cache: a second explorer over the same directory serves disk
/// hits that re-serialize to identical bytes, across thread counts.
#[test]
fn disk_cache_roundtrip_bit_identical() {
    let dir = std::env::temp_dir().join(format!("finn-mvu-explore-it-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let points = SweepKind::IfmChannels.points(SimdType::Standard);

    let cfg = |threads: usize| ExploreConfig {
        threads,
        sim_vectors: 1,
        cache_dir: Some(dir.clone()),
    };
    let first = Explorer::new(cfg(1)).unwrap().evaluate_points(&points).unwrap();
    let second_ex = Explorer::new(cfg(8)).unwrap();
    let second = second_ex.evaluate_points(&points).unwrap();
    let stats = second_ex.cache_stats();
    assert_eq!(stats.misses, 0, "fresh explorer must be served from disk: {stats:?}");
    assert!(stats.disk_hits > 0);
    assert_eq!(
        points_to_json(&first).to_string(),
        points_to_json(&second).to_string(),
        "disk-cached reports must be byte-identical"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Illegal folds can no longer reach the engine at all — `SweepPoint`
/// carries a `ValidatedParams`, so the old "invalid point mid-sweep"
/// failure mode is unrepresentable. What remains observable is that
/// *when* per-point work fails, the error of the smallest failing index
/// wins at every thread count.
#[test]
fn error_reporting_is_deterministic_across_thread_counts() {
    // the type system rejects unvalidated points at the boundary
    assert!(DesignPoint::conv("illegal")
        .ifm_ch(64)
        .ifm_dim(8)
        .ofm_ch(64)
        .kernel_dim(4)
        .pe(64)
        .simd(7) // does not divide K^2*IC = 1024
        .build()
        .is_err());

    // and failing jobs keep deterministic first-failure semantics
    let items: Vec<usize> = (0..24).collect();
    let errs: Vec<String> = [1usize, 2, 8]
        .into_iter()
        .map(|t| {
            let results = Explorer::with_threads(t).par_map(&items, |i, &v| {
                if v % 7 == 2 {
                    anyhow::bail!("job {i} failed")
                }
                Ok(v)
            });
            results
                .into_iter()
                .find_map(|r| r.err())
                .expect("some jobs must fail")
                .to_string()
        })
        .collect();
    assert_eq!(errs[0], "job 2 failed");
    assert_eq!(errs[0], errs[1]);
    assert_eq!(errs[1], errs[2]);
}
