//! Golden-file regression for the estimator: `estimate()` outputs
//! (LUT/FF/BRAM/delay/synth-time) for every Table 2 sweep configuration
//! under all three SIMD types, snapshotted under `tests/golden/` and
//! diffed on every run, so estimator refactors cannot silently drift from
//! the paper-calibrated numbers.
//!
//! Workflow (insta-style):
//!   * first run in a fresh checkout writes the snapshot and passes
//!     (commit the generated file);
//!   * later runs diff against the snapshot and fail on any byte change;
//!   * `GOLDEN_UPDATE=1 cargo test golden` re-blesses after an
//!     intentional model change.

use std::path::PathBuf;

use finn_mvu::cfg::SimdType;
use finn_mvu::explore::{points_to_json, Explorer};
use finn_mvu::harness::SweepKind;
use finn_mvu::util::json::Json;

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden/table2_estimates.json")
}

/// Build the snapshot document through the (serial) exploration engine —
/// deterministic key order and float formatting come from the in-tree
/// JSON writer.
fn build_snapshot() -> Json {
    let ex = Explorer::serial();
    let mut sweeps = Json::obj();
    for kind in SweepKind::ALL {
        for ty in SimdType::ALL {
            let reports = ex.evaluate_points(&kind.points(ty)).unwrap();
            sweeps.set(&format!("{}/{}", kind.label(), ty.name()), points_to_json(&reports));
        }
    }
    let mut doc = Json::obj();
    doc.set("schema", Json::Str("table2-estimates-v1".to_string()));
    doc.set("sweeps", sweeps);
    doc
}

#[test]
fn golden_table2_estimates() {
    let path = golden_path();
    let got = build_snapshot().to_pretty(2) + "\n";
    let update = std::env::var("GOLDEN_UPDATE").is_ok_and(|v| !v.is_empty() && v != "0");
    if update || !path.exists() {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, &got).unwrap();
        eprintln!(
            "golden_table2_estimates: {} snapshot at {} — commit it so future runs diff \
             against it",
            if update { "re-blessed" } else { "bootstrapped" },
            path.display()
        );
        return;
    }
    let want = std::fs::read_to_string(&path).unwrap();
    if got != want {
        // surface the first diverging line so the failure is actionable
        let mismatch = got
            .lines()
            .zip(want.lines())
            .enumerate()
            .find(|(_, (g, w))| g != w)
            .map(|(i, (g, w))| format!("line {}: now {:?}, golden {:?}", i + 1, g, w))
            .unwrap_or_else(|| {
                format!("length changed: now {} lines, golden {}", got.lines().count(),
                    want.lines().count())
            });
        panic!(
            "estimator output drifted from {}:\n  {}\n(if the change is intentional, \
             re-bless with GOLDEN_UPDATE=1 cargo test golden)",
            path.display(),
            mismatch
        );
    }
}

/// The snapshot builder itself must be deterministic — two builds in the
/// same process serialize identically (guards against map-ordering or
/// float-formatting regressions in the writer).
#[test]
fn golden_snapshot_is_deterministic() {
    assert_eq!(build_snapshot().to_string(), build_snapshot().to_string());
}
