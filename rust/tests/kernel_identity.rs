//! Bit-identity between the two simulation kernels (DESIGN.md §Two-kernel
//! simulator): the batched/interval-skipping production kernel
//! (`sim::fast`, behind `run_mvu*`) must reproduce the per-cycle oracle
//! (`sim::reference`) field-for-field — output streams, exact cycle
//! counts, stall/backpressure counters, slot counts and the FIFO
//! occupancy high-water mark — over the full Table 2 grid and under
//! arbitrary stall patterns and FIFO depths.

use std::sync::Arc;

use finn_mvu::cfg::{DesignPoint, LayerParams, SimdType, ValidatedParams};
use finn_mvu::explore::{stimulus_inputs, stimulus_seed, stimulus_weights};
use finn_mvu::harness::SweepKind;
use finn_mvu::proptest::{check, Config, Gen};
use finn_mvu::quant::Matrix;
use finn_mvu::sim::{
    reference, run_mvu_fifo, run_mvu_shared, PackedWeightMem, SharedWeights, StallPattern,
    WeightMem, DEFAULT_FIFO_DEPTH,
};

/// Every Table 2 sweep configuration under all three SIMD types, with the
/// engine's canonical deterministic stimulus (fold-independent seed, the
/// one sweeps actually run): the fast kernel's report — packed datapath
/// for Xnor/BinaryWeights, flat for Standard — must equal the oracle's
/// byte for byte. Run under `--release` in CI as well: wrapping/overflow
/// divergences between the SWAR identities and the slot-wise kernels
/// would hide behind debug_asserts in dev builds.
#[test]
fn kernels_identical_over_table2_grid() {
    let mut points = 0usize;
    for kind in SweepKind::ALL {
        for ty in SimdType::ALL {
            for sp in kind.points(ty) {
                let p = &sp.params;
                let seed = stimulus_seed(p);
                let w = stimulus_weights(p, seed);
                let inputs = stimulus_inputs(p, seed ^ 0x9e37_79b9_7f4a_7c15, 2);
                let fast = run_mvu_fifo(
                    p,
                    &w,
                    &inputs,
                    StallPattern::None,
                    StallPattern::None,
                    DEFAULT_FIFO_DEPTH,
                )
                .unwrap();
                let oracle = reference::run_mvu_fifo(
                    p,
                    &w,
                    &inputs,
                    StallPattern::None,
                    StallPattern::None,
                    DEFAULT_FIFO_DEPTH,
                )
                .unwrap();
                assert_eq!(fast, oracle, "{p}");
                points += 1;
            }
        }
    }
    assert!(points > 50, "grid unexpectedly small: {points} points");
}

/// The blocked multi-vector datapath (DESIGN.md §Batched datapath)
/// across batch sizes straddling the blocking sweet spot: the fast
/// kernel evaluates the whole batch row-major (one weight word load per
/// row word, reused across the batch), the oracle strictly
/// vector-by-vector — the reports must still match field for field.
/// Heavy grid points (the kernel-dim sweep reaches ~83k slots/vector)
/// are capped to keep the per-cycle oracle affordable in dev builds;
/// the batch-size coverage floor below pins that the cap still leaves
/// the grid's breadth intact.
#[test]
fn kernels_identical_across_batch_sizes() {
    let mut covered = 0usize;
    for kind in SweepKind::ALL {
        for ty in SimdType::ALL {
            for sp in kind.points(ty) {
                let p = &sp.params;
                let slots_per_vec = (p.matrix_rows() / p.pe) * (p.matrix_cols() / p.simd);
                if slots_per_vec > 2048 {
                    continue; // covered at n=2 by kernels_identical_over_table2_grid
                }
                let seed = stimulus_seed(p);
                let w = stimulus_weights(p, seed);
                let all = stimulus_inputs(p, seed ^ 0x9e37_79b9_7f4a_7c15, 33);
                for b in [1usize, 2, 31, 32, 33] {
                    let inputs = &all[..b];
                    let fast = run_mvu_fifo(
                        p,
                        &w,
                        inputs,
                        StallPattern::None,
                        StallPattern::None,
                        DEFAULT_FIFO_DEPTH,
                    )
                    .unwrap();
                    let oracle = reference::run_mvu_fifo(
                        p,
                        &w,
                        inputs,
                        StallPattern::None,
                        StallPattern::None,
                        DEFAULT_FIFO_DEPTH,
                    )
                    .unwrap();
                    assert_eq!(fast, oracle, "{p} batch={b}");
                }
                covered += 1;
            }
        }
    }
    assert!(covered >= 60, "batch-size coverage unexpectedly small: {covered} points");
}

/// Malformed input vectors (wrong lane count) are a structured error —
/// not a panic — from BOTH kernels, with identical messages, on the
/// ideal closed-form flow and the stalled stepped flow alike.
#[test]
fn malformed_vectors_error_identically() {
    let p = DesignPoint::fc("malformed")
        .in_features(12)
        .out_features(4)
        .pe(2)
        .simd(4)
        .precision(2, 2, 0)
        .build()
        .unwrap();
    let seed = stimulus_seed(&p);
    let w = stimulus_weights(&p, seed);
    // vector 1 is short among well-formed neighbours
    let mut inputs = stimulus_inputs(&p, seed ^ 3, 3);
    inputs[1].truncate(5);
    let stall = StallPattern::Periodic { period: 3, duty: 1, phase: 0 };
    for out_s in [StallPattern::None, stall] {
        let fast =
            run_mvu_fifo(&p, &w, &inputs, StallPattern::None, out_s.clone(), DEFAULT_FIFO_DEPTH);
        let oracle = reference::run_mvu_fifo(
            &p,
            &w,
            &inputs,
            StallPattern::None,
            out_s,
            DEFAULT_FIFO_DEPTH,
        );
        let (fe, oe) = (fast.unwrap_err(), oracle.unwrap_err());
        assert_eq!(fe.to_string(), oe.to_string());
        assert_eq!(fe.to_string(), "input vector 1 has 5 lanes, expected 12");
    }
}

/// The empty batch: no vectors means no execution beyond the idle
/// cycle — both kernels agree and report `exec_cycles == 1` with an
/// untouched FIFO.
#[test]
fn zero_vectors_report_exec_cycles_one() {
    for ty in SimdType::ALL {
        let (wb, ib) = match ty {
            SimdType::Xnor => (1, 1),
            SimdType::BinaryWeights => (1, 2),
            SimdType::Standard => (4, 4),
        };
        let p = DesignPoint::fc("empty")
            .in_features(8)
            .out_features(4)
            .pe(2)
            .simd(4)
            .simd_type(ty)
            .precision(wb, ib, 0)
            .build()
            .unwrap();
        let w = stimulus_weights(&p, stimulus_seed(&p));
        let inputs: Vec<Vec<i32>> = Vec::new();
        let fast = run_mvu_fifo(
            &p,
            &w,
            &inputs,
            StallPattern::None,
            StallPattern::None,
            DEFAULT_FIFO_DEPTH,
        )
        .unwrap();
        let oracle = reference::run_mvu_fifo(
            &p,
            &w,
            &inputs,
            StallPattern::None,
            StallPattern::None,
            DEFAULT_FIFO_DEPTH,
        )
        .unwrap();
        assert_eq!(fast, oracle, "{ty}");
        assert_eq!(fast.exec_cycles, 1, "{ty}");
        assert_eq!(fast.slots_consumed, 0, "{ty}");
        assert_eq!(fast.fifo_max_occupancy, 0, "{ty}");
    }
}

/// Property: one blocked run over B vectors produces exactly the
/// outputs of B independent single-vector runs — the regrouping of
/// wrapping adds behind the blocked traversal changes nothing, on any
/// SIMD type, at any batch size.
#[test]
fn prop_blocked_equals_independent_runs() {
    check("blocked == B independent runs", Config::cases(60), |g| {
        let p = arb_params(g);
        let w = arb_weights(g, &p);
        let b = g.usize_in(1, 36);
        let inputs = arb_inputs(g, &p, b);
        let batched = run_mvu_fifo(
            &p,
            &w,
            &inputs,
            StallPattern::None,
            StallPattern::None,
            DEFAULT_FIFO_DEPTH,
        )
        .map_err(|e| format!("{p} batch={b}: {e:#}"))?;
        for (i, v) in inputs.iter().enumerate() {
            let single = run_mvu_fifo(
                &p,
                &w,
                std::slice::from_ref(v),
                StallPattern::None,
                StallPattern::None,
                DEFAULT_FIFO_DEPTH,
            )
            .map_err(|e| format!("{p} vector {i}: {e:#}"))?;
            if single.outputs[0] != batched.outputs[i] {
                return Err(format!(
                    "{p} batch={b}: vector {i} diverges: single {:?} != blocked {:?}",
                    single.outputs[0], batched.outputs[i]
                ));
            }
        }
        Ok(())
    });
}

fn arb_params(g: &mut Gen) -> ValidatedParams {
    let ty = *g.choose(&SimdType::ALL);
    let (wb, ib) = match ty {
        SimdType::Xnor => (1, 1),
        SimdType::BinaryWeights => (1, *g.choose(&[2u32, 4])),
        SimdType::Standard => (*g.choose(&[2u32, 4]), *g.choose(&[2u32, 4])),
    };
    let rows = g.usize_in(1, 14);
    let cols = g.usize_in(1, 40);
    let pe = g.divisor_of(rows);
    let simd = g.divisor_of(cols);
    DesignPoint::fc("ident")
        .in_features(cols)
        .out_features(rows)
        .pe(pe)
        .simd(simd)
        .simd_type(ty)
        .precision(wb, ib, 0)
        .build()
        .expect("generated folds are divisors, hence legal")
}

fn arb_weights(g: &mut Gen, p: &LayerParams) -> Matrix {
    let (r, c) = (p.matrix_rows(), p.matrix_cols());
    let data: Vec<i32> = (0..r * c)
        .map(|_| match p.simd_type {
            SimdType::Xnor | SimdType::BinaryWeights => g.i32_in(0, 1),
            SimdType::Standard => {
                let half = 1 << (p.weight_bits - 1);
                g.i32_in(-half, half - 1)
            }
        })
        .collect();
    Matrix::new(r, c, data).unwrap()
}

fn arb_inputs(g: &mut Gen, p: &LayerParams, n: usize) -> Vec<Vec<i32>> {
    (0..n)
        .map(|_| {
            (0..p.matrix_cols())
                .map(|_| match p.simd_type {
                    SimdType::Xnor => g.i32_in(0, 1),
                    _ => {
                        let half = 1 << (p.input_bits - 1);
                        g.i32_in(-half, half - 1)
                    }
                })
                .collect()
        })
        .collect()
}

/// Any pattern the public API accepts, including ones that never make
/// progress (the kernels must then agree on the deadlock failure too).
fn arb_stall(g: &mut Gen) -> StallPattern {
    match g.usize_in(0, 3) {
        0 => StallPattern::None,
        1 => {
            let period = g.usize_in(1, 9);
            StallPattern::Periodic {
                period,
                duty: g.usize_in(0, period),
                phase: g.usize_in(0, 6),
            }
        }
        2 => StallPattern::Random { seed: g.rng.next_u64(), p_num: g.usize_in(0, 220) as u32 },
        _ => StallPattern::Schedule((0..g.usize_in(0, 10)).map(|_| g.chance(128)).collect()),
    }
}

/// Stalled flows, all FIFO depths, both PRNG-driven and deterministic
/// patterns: identical `Ok` reports or identical `Err` messages.
#[test]
fn prop_kernels_identical_under_stalls() {
    check("fast==reference", Config::cases(80), |g| {
        let p = arb_params(g);
        let w = arb_weights(g, &p);
        let n = g.usize_in(0, 4);
        let inputs = arb_inputs(g, &p, n);
        let in_stall = arb_stall(g);
        let out_stall = arb_stall(g);
        let depth = g.usize_in(1, 6);
        let fast = run_mvu_fifo(&p, &w, &inputs, in_stall.clone(), out_stall.clone(), depth);
        let oracle =
            reference::run_mvu_fifo(&p, &w, &inputs, in_stall.clone(), out_stall.clone(), depth);
        match (fast, oracle) {
            (Ok(a), Ok(b)) => {
                if a != b {
                    return Err(format!(
                        "{p} depth={depth} ({in_stall:?}/{out_stall:?}): fast {a:?} != oracle {b:?}"
                    ));
                }
                Ok(())
            }
            (Err(a), Err(b)) => {
                if a.to_string() != b.to_string() {
                    return Err(format!(
                        "{p} depth={depth}: error divergence: fast {a:#} vs oracle {b:#}"
                    ));
                }
                Ok(())
            }
            (a, b) => Err(format!(
                "{p} depth={depth} ({in_stall:?}/{out_stall:?}): one kernel failed: fast \
                 {:?} vs oracle {:?}",
                a.map(|r| r.exec_cycles),
                b.map(|r| r.exec_cycles)
            )),
        }
    });
}

/// The fold-block numerics agree with the oracle on every SIMD type at
/// sizes that straddle the fold-block width.
#[test]
fn kernels_identical_on_wide_rows() {
    for ty in SimdType::ALL {
        let (wb, ib) = match ty {
            SimdType::Xnor => (1, 1),
            SimdType::BinaryWeights => (1, 4),
            SimdType::Standard => (4, 4),
        };
        let p = DesignPoint::fc("wide")
            .in_features(200)
            .out_features(6)
            .pe(3)
            .simd(8)
            .simd_type(ty)
            .precision(wb, ib, 0)
            .build()
            .unwrap();
        let seed = stimulus_seed(&p);
        let w = stimulus_weights(&p, seed);
        let inputs = stimulus_inputs(&p, seed ^ 1, 3);
        let fast = run_mvu_fifo(
            &p,
            &w,
            &inputs,
            StallPattern::None,
            StallPattern::None,
            DEFAULT_FIFO_DEPTH,
        )
        .unwrap();
        let oracle = reference::run_mvu_fifo(
            &p,
            &w,
            &inputs,
            StallPattern::None,
            StallPattern::None,
            DEFAULT_FIFO_DEPTH,
        )
        .unwrap();
        assert_eq!(fast, oracle, "{ty}");
    }
}

/// The sweep-sharing contract end to end: one bit packing (and one flat
/// memory per folding) built once and shared via `Arc` across every fold
/// variant of a layer — exactly what the explore engine's stimulus memo
/// does — must reproduce the oracle bit-for-bit on ideal *and* stalled
/// flows, for both 1-bit SIMD types.
#[test]
fn shared_packing_identical_across_fold_sweep() {
    for ty in [SimdType::Xnor, SimdType::BinaryWeights] {
        // one layer (64 cols x 8 rows), the matrix packed exactly once
        let base = DesignPoint::fc("share")
            .in_features(64)
            .out_features(8)
            .pe(1)
            .simd(1)
            .paper_precision(ty)
            .build()
            .unwrap();
        let seed = stimulus_seed(&base);
        let w = stimulus_weights(&base, seed);
        let inputs = stimulus_inputs(&base, seed ^ 0x9e37_79b9_7f4a_7c15, 2);
        let packed = Arc::new(PackedWeightMem::from_matrix(&w).unwrap());
        let out_stall = StallPattern::Periodic { period: 6, duty: 2, phase: 1 };
        for (pe, simd) in [(1usize, 1usize), (2, 4), (4, 16), (8, 64)] {
            let p = DesignPoint::fc("share")
                .in_features(64)
                .out_features(8)
                .pe(pe)
                .simd(simd)
                .paper_precision(ty)
                .build()
                .unwrap();
            assert_eq!(stimulus_seed(&p), seed, "stimulus seed must be fold-independent");
            let shared = SharedWeights {
                mem: Some(Arc::new(WeightMem::from_matrix(&p, &w).unwrap())),
                packed: Some(packed.clone()),
            };
            for out_s in [StallPattern::None, out_stall.clone()] {
                let fast = run_mvu_shared(
                    &p,
                    &w,
                    &shared,
                    &inputs,
                    StallPattern::None,
                    out_s.clone(),
                    DEFAULT_FIFO_DEPTH,
                )
                .unwrap();
                let oracle = reference::run_mvu_fifo(
                    &p,
                    &w,
                    &inputs,
                    StallPattern::None,
                    out_s,
                    DEFAULT_FIFO_DEPTH,
                )
                .unwrap();
                assert_eq!(fast, oracle, "{ty} pe={pe} simd={simd}");
            }
        }
    }
}

/// Operands outside the packable range (a non-bit lane in a 1-bit
/// position) must route the fast kernel onto the flat fallback and still
/// match the oracle — in release builds too, where no debug_assert can
/// mask a divergence.
#[test]
fn unpackable_weights_fall_back_identically() {
    let p = DesignPoint::fc("nonbit")
        .in_features(12)
        .out_features(4)
        .pe(2)
        .simd(4)
        .simd_type(SimdType::BinaryWeights)
        .precision(1, 4, 0)
        .build()
        .unwrap();
    let mut data = vec![0i32; 48];
    for (i, v) in data.iter_mut().enumerate() {
        *v = (i % 2) as i32;
    }
    data[7] = 3; // never representable in one weight bit
    let w = Matrix::new(4, 12, data).unwrap();
    let inputs = vec![(0..12).map(|i| i - 6).collect::<Vec<i32>>()];
    let fast = run_mvu_fifo(
        &p,
        &w,
        &inputs,
        StallPattern::None,
        StallPattern::None,
        DEFAULT_FIFO_DEPTH,
    )
    .unwrap();
    let oracle = reference::run_mvu_fifo(
        &p,
        &w,
        &inputs,
        StallPattern::None,
        StallPattern::None,
        DEFAULT_FIFO_DEPTH,
    )
    .unwrap();
    assert_eq!(fast, oracle);
}
