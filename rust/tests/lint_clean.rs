//! The repository passes its own static analysis (DESIGN.md §Static
//! analysis): `finn-mvu lint` over the full tree must report zero
//! unsuppressed findings. This is the enforcement half of the
//! self-hosted lint subsystem — every determinism, panic-path,
//! kernel-drift, doc-drift and style invariant the `analysis` module
//! checks is a hard CI gate, and a finding either gets fixed or gets a
//! per-site `// lint: allow(<pass>, <reason>)` that shows up in the
//! suppression count below.

use finn_mvu::analysis::{self, RepoModel};

/// Every pass over the real tree: zero unsuppressed findings. On
/// failure the rendered findings are printed so CI output names the
/// offending file/line/pass directly.
#[test]
fn repository_is_lint_clean() {
    let root = analysis::repo_root().expect("repo root");
    let model = RepoModel::load(&root).expect("load repo model");
    let analysis = analysis::run(&model).expect("run passes");
    if !analysis.is_clean() {
        let mut msg = String::new();
        for f in analysis.unsuppressed() {
            msg.push_str(&format!("{}:{}  [{}] {}\n", f.file, f.line, f.pass, f.message));
        }
        panic!("unsuppressed lint findings:\n{msg}");
    }
}

/// The suppression mechanism end-to-end: the tree's own suppressed
/// findings carry their annotation reasons, and the per-pass counts
/// stay visible (a silently-ignored pass would show zero findings AND
/// zero suppressions everywhere, which the sim panic-path annotations
/// rule out).
#[test]
fn suppressions_carry_reasons() {
    let root = analysis::repo_root().expect("repo root");
    let model = RepoModel::load(&root).expect("load repo model");
    let analysis = analysis::run(&model).expect("run passes");
    let suppressed: Vec<_> =
        analysis.findings.iter().filter(|f| f.suppressed.is_some()).collect();
    // the sim FSM invariants are annotated, never silently dropped
    assert!(
        suppressed.iter().any(|f| f.pass == "panic-path"),
        "expected annotated panic-path invariant sites in rust/src/sim/"
    );
    for f in &suppressed {
        let reason = f.suppressed.as_ref().unwrap();
        assert!(
            !reason.is_empty(),
            "{}:{} suppressed without a reason",
            f.file,
            f.line
        );
    }
}

/// The kernel fingerprint manifest is present, parses, and matches both
/// the tree and `sim::SIM_KERNEL_VERSION` — the drift pass has real
/// inputs, not a vacuous pass-by-absence.
#[test]
fn fingerprint_manifest_matches_tree() {
    let root = analysis::repo_root().expect("repo root");
    let model = RepoModel::load(&root).expect("load repo model");
    assert_eq!(model.kernel_version, Some(finn_mvu::sim::SIM_KERNEL_VERSION));
    let manifest = model.fingerprint_manifest.as_deref().expect("sim.fingerprint exists");
    let parsed = analysis::drift::parse_manifest(manifest).expect("manifest parses");
    assert_eq!(parsed.version, finn_mvu::sim::SIM_KERNEL_VERSION);
    assert_eq!(parsed.entries, analysis::drift::current_entries(&model));
}
