//! The paper's headline claims, asserted over the full sweep grids — the
//! acceptance tests of the reproduction (DESIGN.md §3 "expected shapes").

use finn_mvu::cfg::{nid_layers, SimdType};
use finn_mvu::estimate::{estimate, PathLocation, Style};
use finn_mvu::harness::{resource_sweep_figure, table5, table7, SweepKind};

const ALL_SWEEPS: [SweepKind; 6] = [
    SweepKind::IfmChannels,
    SweepKind::KernelDim,
    SweepKind::OfmChannels,
    SweepKind::IfmDim,
    SweepKind::Pe,
    SweepKind::Simd,
];

/// §6.2.3: "HLS uses more [flip-flops] for all types of designs".
#[test]
fn claim_hls_always_more_ffs() {
    for kind in ALL_SWEEPS {
        for ty in SimdType::ALL {
            for p in resource_sweep_figure(kind, ty).unwrap().points {
                assert!(
                    p.ffs_hls > p.ffs_rtl,
                    "{kind:?}/{ty}@{}: HLS {} vs RTL {} FFs",
                    p.swept,
                    p.ffs_hls,
                    p.ffs_rtl
                );
            }
        }
    }
}

/// Abstract: "for smaller design parameters, RTL produces significantly
/// smaller circuits ... for larger circuits the LUT count of RTL is
/// slightly higher, up to around 15%" (we allow up to 30% in the model).
#[test]
fn claim_lut_crossover() {
    // smallest point of the IFM sweep: HLS much larger
    for ty in SimdType::ALL {
        let s = resource_sweep_figure(SweepKind::IfmChannels, ty).unwrap();
        let p0 = &s.points[0];
        assert!(p0.luts_hls as f64 > 1.5 * p0.luts_rtl as f64, "{ty}: no small-design gap");
    }
    // largest point of the SIMD sweep: RTL >= HLS but within ~30%
    let s = resource_sweep_figure(SweepKind::Simd, SimdType::Standard).unwrap();
    let pl = s.points.last().unwrap();
    let ratio = pl.luts_rtl as f64 / pl.luts_hls as f64;
    assert!(ratio >= 1.0, "expected RTL slightly larger at scale, ratio {ratio:.2}");
    assert!(ratio <= 1.35, "RTL excess too large: {ratio:.2}");
}

/// §6.3: RTL faster in all cases; 45-80% for the mean across sweeps.
#[test]
fn claim_rtl_speedup_45_to_80_percent() {
    let (_, rows) = table5().unwrap();
    for r in &rows {
        let speedup = (r.hls.mean - r.rtl.mean) / r.hls.mean;
        assert!(speedup > 0.0, "{} {}: no speedup", r.parameter, r.simd_type);
    }
    // the standard type (the paper's 80% case) must show a large gap
    let std_rows: Vec<_> = rows.iter().filter(|r| r.simd_type == SimdType::Standard).collect();
    for r in std_rows {
        let speedup = (r.hls.mean - r.rtl.mean) / r.hls.mean;
        assert!(
            (0.45..=0.90).contains(&speedup),
            "{}: standard speedup {speedup:.2} outside paper band",
            r.parameter
        );
    }
}

/// §6.3.1: critical path location — control for small RTL designs, SIMD
/// element / adder tree at scale.
#[test]
fn claim_critical_path_location() {
    let small = &finn_mvu::cfg::sweep_ifm_channels(SimdType::Xnor)[0].params;
    assert_eq!(estimate(small, Style::Rtl).delay_location, PathLocation::Control);
    let large = finn_mvu::cfg::sweep_simd(SimdType::Standard).last().unwrap().params.clone();
    let loc = estimate(&large, Style::Rtl).delay_location;
    assert_ne!(loc, PathLocation::Control);
}

/// §6.4 + Table 7: HLS synthesis at least ~10x slower on the NID layers;
/// exec cycles match the paper exactly.
#[test]
fn claim_nid_table7() {
    let (_, rows) = table7(None).unwrap();
    for r in &rows {
        assert!(
            r.synth_s.0 / r.synth_s.1 >= 4.0,
            "{}: synth ratio {:.1}",
            r.layer,
            r.synth_s.0 / r.synth_s.1
        );
        assert!(r.delay_ns.1 < r.delay_ns.0, "{}: RTL not faster", r.layer);
    }
    assert_eq!(
        rows.iter().map(|r| r.exec_cycles.1).collect::<Vec<_>>(),
        vec![17, 13, 13, 13],
        "RTL exec cycles vs paper Table 7"
    );
    assert_eq!(
        rows.iter().map(|r| r.exec_cycles.0).collect::<Vec<_>>(),
        vec![17, 13, 13, 12],
        "HLS exec cycles vs paper Table 7"
    );
}

/// Paper Table 7: both implementations reach II=1 — cycles equal between
/// HLS and RTL up to fill latency, and equal to the analytic fold.
#[test]
fn claim_ii_of_one() {
    for p in nid_layers() {
        let fold = p.synapse_fold() * p.neuron_fold() * p.output_pixels();
        let cycles = p.analytic_cycles(finn_mvu::sim::PIPELINE_STAGES);
        assert!(cycles - fold <= 6, "{}: fill latency too large", p.name);
    }
}

/// §6.2.1: execution cycles scale with IFM dim (re-use of the same core),
/// while resources stay constant (Fig. 11).
#[test]
fn claim_fig11_reuse() {
    let s = resource_sweep_figure(SweepKind::IfmDim, SimdType::BinaryWeights).unwrap();
    let base = &s.points[0];
    for p in &s.points[1..] {
        // near-flat: only the pixel counters widen (a handful of LUTs)
        let rel = (p.luts_rtl as f64 - base.luts_rtl as f64).abs() / base.luts_rtl as f64;
        assert!(rel < 0.005, "RTL LUTs vary with IFM dim: {} vs {}", p.luts_rtl, base.luts_rtl);
        assert!(p.cycles > base.cycles);
    }
}
