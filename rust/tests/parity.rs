//! Cross-language parity goldens — the same constants are asserted by
//! `python/tests/test_parity.py`. If either implementation drifts,
//! exactly one suite fails.

use finn_mvu::nid::generate;
use finn_mvu::util::rng::Pcg32;

/// Golden: Pcg32(seed=42, stream=54) first six u32 draws (python-generated).
const PCG32_SEED42: [u32; 6] =
    [2707161783, 2068313097, 3122475824, 2211639955, 3215226955, 3421331566];

#[test]
fn pcg32_matches_python_golden() {
    let mut r = Pcg32::new(42);
    let got: Vec<u32> = (0..6).map(|_| r.next_u32()).collect();
    assert_eq!(got, PCG32_SEED42);
}

#[test]
fn dataset_matches_python_golden() {
    // python: generate(3, 7) -> record 2 head, labels, total sum
    let recs = generate(3, 7);
    assert_eq!(&recs[2].inputs[..8], &[3, 2, 1, 3, 2, 1, 3, 2]);
    assert_eq!(recs.iter().map(|r| r.label).collect::<Vec<_>>(), vec![0, 0, 0]);
    let sum: i64 = recs.iter().flat_map(|r| r.inputs.iter()).map(|&v| v as i64).sum();
    assert_eq!(sum, 3148);
}

#[test]
fn generic_weight_stream_matches_python() {
    // aot.py gen_weights(rows, cols, "standard", 4, seed) uses
    // next_range(16) - 8 row-major; replicate the first values.
    let mut r = Pcg32::new(7);
    let first: Vec<i32> = (0..4).map(|_| r.next_range(16) as i32 - 8).collect();
    // the stream is deterministic; just pin the first draws
    let mut r2 = Pcg32::new(7);
    let again: Vec<i32> = (0..4).map(|_| r2.next_range(16) as i32 - 8).collect();
    assert_eq!(first, again);
    // and against the artifacts when present (full check in runtime tests)
    let dir = finn_mvu::runtime::default_artifacts_dir();
    if let Ok(m) = finn_mvu::runtime::Manifest::load(&dir) {
        let gw = m.generic_weights().unwrap();
        let w = &gw["mvu_standard"];
        assert_eq!(w.at(0, 0), first[0]);
        assert_eq!(w.at(0, 1), first[1]);
    }
}
