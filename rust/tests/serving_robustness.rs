//! Serving-frontend robustness suite (ISSUE: resilient serving PR).
//!
//! Pins the tentpole's contract end-to-end against the real
//! [`Session`] backend:
//!
//! * conservation (`offered == completed + rejected + dropped +
//!   timed_out`) under every shed policy x fault mix, at the id level
//!   as well as the counter level;
//! * byte-identical summaries and payloads across session thread
//!   counts {1, 2, 8};
//! * the all-policies-disabled path byte-identical to calling
//!   [`Session::evaluate`] directly;
//! * breaker recovery after an injected outage window;
//! * the pipeline dead-worker fix (structured [`DeadWorker`] instead of
//!   a hang) through the public [`Pipeline::run_with`] API;
//! * a corrupted on-disk cache entry quarantined and recomputed
//!   through a full `Session` evaluation.

use std::sync::Arc;

use finn_mvu::cfg::{DesignPoint, ValidatedParams};
use finn_mvu::coordinator::{
    DeadWorker, KernelFactory, Pipeline, PipelineConfig, Request, UnitKernel,
};
use finn_mvu::device::RetryPolicy;
use finn_mvu::estimate::Style;
use finn_mvu::eval::{EvalRequest, Session, SessionConfig, SimOptions};
use finn_mvu::explore::{content_hash, estimate_key};
use finn_mvu::serve::{
    evaluation_to_json, run_frontend, synthetic_load, BreakerPolicy, FaultyBackend,
    InjectedFaults, RatePolicy, ServeKind, ServePolicy, ServeRequest, SessionBackend, Shed, Tier,
};

fn point(name: &str) -> ValidatedParams {
    DesignPoint::fc(name)
        .in_features(16)
        .out_features(8)
        .pe(4)
        .simd(8)
        .precision(4, 4, 0)
        .build()
        .unwrap()
}

#[test]
fn conservation_holds_under_every_shed_policy_and_fault_mix() {
    let session = Session::serial();
    let p = point("conserve");
    let kinds = [
        ServeKind::Evaluate(Arc::new(EvalRequest::new(p.clone()))),
        ServeKind::CacheQuery { key: estimate_key(&p, Style::Rtl) },
    ];
    let plans = [
        InjectedFaults::none(),
        InjectedFaults::none().with_every(Tier::Full, 3),
        InjectedFaults::none().with_outage(Tier::Full, 100, 2_000).with_every(Tier::Fast, 2),
    ];
    for shed in [Shed::RejectNew, Shed::DropOldest] {
        for plan in &plans {
            let reqs = synthetic_load(300, 3.0, 11, &kinds);
            let policy = ServePolicy {
                queue_depth: 16,
                shed,
                rate: Some(RatePolicy { burst: 32, per: 4 }),
                deadline: Some(1_500),
                batch: 4,
                max_wait: 16,
                retry: RetryPolicy {
                    max_attempts: 2,
                    backoff_base: 8,
                    backoff_cap: 64,
                    jitter: 4,
                },
                service: [40, 10, 2, 1],
                ..ServePolicy::default()
            };
            let inner = SessionBackend::new(&session);
            let faulty = FaultyBackend::new(&inner, plan.clone());
            let out = run_frontend(&faulty, &reqs, &policy).unwrap();
            let s = &out.summary;
            assert!(s.conserved(), "shed {shed:?} plan {plan:?}: {s:?}");
            let fates = out.responses.len()
                + out.rejected_ids.len()
                + out.dropped_ids.len()
                + out.timed_out_ids.len();
            assert_eq!(fates, 300, "every id gets exactly one fate ({shed:?}, {plan:?})");
        }
    }
}

#[test]
fn outcomes_are_byte_identical_across_session_thread_counts() {
    let p = point("threads");
    let full = Arc::new(EvalRequest::new(p.clone()).with_sim(SimOptions::default()));
    let kinds = [
        ServeKind::Evaluate(full),
        ServeKind::CacheQuery { key: estimate_key(&p, Style::Rtl) },
    ];
    let reqs = synthetic_load(200, 4.0, 5, &kinds);
    let policy = ServePolicy {
        queue_depth: 8,
        shed: Shed::DropOldest,
        deadline: Some(2_000),
        batch: 4,
        max_wait: 8,
        service: [50, 10, 2, 1],
        ..ServePolicy::default()
    };
    let plan = InjectedFaults::none().with_every(Tier::Full, 4);
    let mut golden: Option<(String, Vec<(u64, String, String)>)> = None;
    for threads in [1usize, 2, 8] {
        let session = Session::with_threads(threads);
        let inner = SessionBackend::new(&session);
        let faulty = FaultyBackend::new(&inner, plan.clone());
        let out = run_frontend(&faulty, &reqs, &policy).unwrap();
        assert!(out.summary.conserved());
        let summary = out.summary.to_json().to_string();
        let responses: Vec<(u64, String, String)> = out
            .responses
            .iter()
            .map(|r| (r.id, r.tier.name().to_string(), r.payload.to_string()))
            .collect();
        match &golden {
            None => golden = Some((summary, responses)),
            Some((gs, gr)) => {
                assert_eq!(&summary, gs, "summary differs at {threads} threads");
                assert_eq!(&responses, gr, "responses differ at {threads} threads");
            }
        }
    }
}

#[test]
fn disabled_policy_is_byte_identical_to_direct_evaluation() {
    let session = Session::serial();
    let pa = point("ident-a");
    let pb = DesignPoint::from_params(point("ident-b").into_inner()).pe(8).build().unwrap();
    let shapes = [
        Arc::new(EvalRequest::new(pa)),
        Arc::new(EvalRequest::new(pb).with_sim(SimOptions::default())),
    ];
    let reqs: Vec<ServeRequest> = (0..6)
        .map(|i| ServeRequest {
            id: i as u64,
            arrive: i as u64 * 10,
            deadline: None,
            kind: ServeKind::Evaluate(shapes[i % 2].clone()),
        })
        .collect();
    let out = session.serve(&reqs, &ServePolicy::disabled()).unwrap();
    let s = &out.summary;
    assert_eq!(s.completed, 6);
    assert_eq!((s.rejected(), s.dropped(), s.timed_out, s.degraded), (0, 0, 0, 0));
    for r in &out.responses {
        assert_eq!(r.tier, Tier::Full, "no guard may degrade a disabled-policy response");
        let direct = session.evaluate(&shapes[r.id as usize % 2]).unwrap();
        assert_eq!(
            r.payload.to_string(),
            evaluation_to_json(&direct).to_string(),
            "request {} must be byte-identical to direct evaluation",
            r.id
        );
    }
}

#[test]
fn breaker_recovers_after_the_outage_window() {
    let session = Session::serial();
    let p = point("recover");
    let kind = ServeKind::Evaluate(Arc::new(EvalRequest::new(p)));
    // one arrival every 100 cycles; the Full tier blacks out for the
    // first 2000 cycles, then comes back
    let reqs: Vec<ServeRequest> = (0..40)
        .map(|i| ServeRequest { id: i, arrive: i * 100, deadline: None, kind: kind.clone() })
        .collect();
    let policy = ServePolicy {
        batch: 1,
        max_wait: 0,
        service: [10, 5, 2, 1],
        breaker: BreakerPolicy { trip_after: 2, open_for: 400, probes: 1 },
        ..ServePolicy::default()
    };
    let inner = SessionBackend::new(&session);
    let plan = InjectedFaults::none().with_outage(Tier::Full, 0, 2_000);
    let faulty = FaultyBackend::new(&inner, plan);
    let out = run_frontend(&faulty, &reqs, &policy).unwrap();
    let s = &out.summary;
    assert!(s.conserved());
    assert!(s.breaker_opens >= 1, "the dead tier must trip its breaker: {s:?}");
    assert!(s.degraded > 0, "the ladder must degrade during the outage: {s:?}");
    let full_after = out
        .responses
        .iter()
        .filter(|r| r.tier == Tier::Full && r.done > 2_000)
        .count();
    assert!(full_after > 0, "the Full tier must serve again after the outage: {s:?}");
}

struct PassKernel;

impl UnitKernel for PassKernel {
    fn out_row(&self) -> usize {
        1
    }

    fn run_batch(&mut self, data: &[i32]) -> anyhow::Result<Vec<i32>> {
        Ok(data.to_vec())
    }
}

/// Builds a pass-through kernel for every layer except index 1.
struct DyingFactory;

impl KernelFactory for DyingFactory {
    fn build(&self, index: usize, name: &str) -> anyhow::Result<Box<dyn UnitKernel>> {
        if index == 1 {
            anyhow::bail!("no kernel for {name}");
        }
        Ok(Box::new(PassKernel))
    }
}

/// Regression (public-API level): a worker whose setup fails used to
/// strand `Pipeline::run` on its start barrier forever; it must now
/// return a structured [`DeadWorker`] naming the layer and the in-flight
/// request ids.
#[test]
fn pipeline_setup_death_is_a_structured_error_not_a_hang() {
    let cfg = PipelineConfig {
        batch: 2,
        channel_depth: 2,
        max_wait: std::time::Duration::from_millis(1),
        arrival_gap: None,
    };
    let names = vec!["l0".to_string(), "l1".to_string()];
    let p = Pipeline::new(std::path::PathBuf::from("unused"), names, cfg);
    let reqs: Vec<Request> = (0..4).map(|id| Request { id, data: vec![id as i32] }).collect();
    let err = p.run_with(&DyingFactory, 1, reqs).unwrap_err();
    let dead = err.downcast_ref::<DeadWorker>().expect("typed DeadWorker");
    assert_eq!((dead.layer, dead.name.as_str()), (1, "l1"));
    assert!(dead.detail.contains("no kernel for l1"), "got: {}", dead.detail);
    assert_eq!(dead.in_flight, vec![0, 1, 2, 3]);
}

#[test]
fn corrupt_disk_cache_entry_is_quarantined_and_recomputed() {
    let dir = std::env::temp_dir().join(format!("finn-mvu-serve-cache-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let p = point("cache-corrupt");
    let req = EvalRequest::new(p.clone());
    let mk = || {
        Session::new(SessionConfig {
            threads: 1,
            cache_dir: Some(dir.clone()),
            ..SessionConfig::default()
        })
        .unwrap()
    };
    let first = mk().evaluate(&req).unwrap();
    let entry = dir.join(format!("{:016x}.json", content_hash(&estimate_key(&p, Style::Rtl))));
    assert!(entry.exists(), "evaluation must publish a disk entry");
    let text = std::fs::read_to_string(&entry).unwrap();
    std::fs::write(&entry, &text[..text.len() / 2]).unwrap(); // torn write
    let session = mk();
    let again = session.evaluate(&req).unwrap();
    assert_eq!(
        evaluation_to_json(&again).to_string(),
        evaluation_to_json(&first).to_string(),
        "a quarantined entry must recompute to the same bytes"
    );
    assert!(session.cache_stats().quarantined >= 1, "{:?}", session.cache_stats());
    assert!(entry.with_extension("json.quarantined").exists());
    std::fs::remove_dir_all(&dir).unwrap();
}
