//! Property tests over the cycle-accurate simulator (own proptest
//! framework, DESIGN.md §6): numerics vs the reference GEMM, the exec-
//! cycle formula, data integrity and liveness under arbitrary stall
//! patterns, and the HLS model's agreement.

use finn_mvu::cfg::{DesignPoint, LayerParams, SimdType, ValidatedParams};
use finn_mvu::proptest::{check, Config, Gen};
use finn_mvu::quant::{matvec, Matrix};
use finn_mvu::sim::{
    run_mvu, run_mvu_fifo, run_mvu_stalled, HlsMvu, StallPattern, PIPELINE_STAGES,
};

/// Draw a random legal MVU configuration (through the builder, so the
/// simulator entry points receive the only type they accept).
fn arb_params(g: &mut Gen) -> ValidatedParams {
    let ty = *g.choose(&SimdType::ALL);
    let (wb, ib) = match ty {
        SimdType::Xnor => (1, 1),
        SimdType::BinaryWeights => (1, *g.choose(&[2u32, 4])),
        SimdType::Standard => (*g.choose(&[2u32, 4]), *g.choose(&[2u32, 4])),
    };
    let rows = g.usize_in(1, 16);
    let cols = g.usize_in(1, 48);
    let pe = g.divisor_of(rows);
    let simd = g.divisor_of(cols);
    DesignPoint::fc("prop")
        .in_features(cols)
        .out_features(rows)
        .pe(pe)
        .simd(simd)
        .simd_type(ty)
        .precision(wb, ib, 0)
        .build()
        .expect("generated folds are divisors, hence legal")
}

fn arb_weights(g: &mut Gen, p: &LayerParams) -> Matrix {
    let (r, c) = (p.matrix_rows(), p.matrix_cols());
    let data: Vec<i32> = (0..r * c)
        .map(|_| match p.simd_type {
            SimdType::Xnor | SimdType::BinaryWeights => g.i32_in(0, 1),
            SimdType::Standard => {
                let half = 1 << (p.weight_bits - 1);
                g.i32_in(-half, half - 1)
            }
        })
        .collect();
    Matrix::new(r, c, data).unwrap()
}

fn arb_inputs(g: &mut Gen, p: &LayerParams, n: usize) -> Vec<Vec<i32>> {
    (0..n)
        .map(|_| {
            (0..p.matrix_cols())
                .map(|_| match p.simd_type {
                    SimdType::Xnor => g.i32_in(0, 1),
                    _ => {
                        let half = 1 << (p.input_bits - 1);
                        g.i32_in(-half, half - 1)
                    }
                })
                .collect()
        })
        .collect()
}

fn arb_stall(g: &mut Gen) -> StallPattern {
    match g.usize_in(0, 3) {
        0 => StallPattern::None,
        1 => {
            // duty < period, or the endpoint never makes progress at all
            let period = g.usize_in(2, 9);
            let duty = g.usize_in(1, period - 1);
            StallPattern::Periodic { period, duty, phase: g.usize_in(0, 5) }
        }
        2 => StallPattern::Random { seed: g.rng.next_u64(), p_num: g.usize_in(1, 200) as u32 },
        _ => {
            // at least one non-stalled slot in the schedule
            let len = g.usize_in(1, 12);
            let mut s: Vec<bool> = (0..len).map(|_| g.chance(100)).collect();
            let free = g.usize_in(0, len - 1);
            s[free] = false;
            StallPattern::Schedule(s)
        }
    }
}

#[test]
fn prop_sim_matches_reference_gemm() {
    check("sim==ref", Config::cases(60), |g| {
        let p = arb_params(g);
        let w = arb_weights(g, &p);
        let n = g.usize_in(1, 4);
        let inputs = arb_inputs(g, &p, n);
        let rep = run_mvu(&p, &w, &inputs).map_err(|e| e.to_string())?;
        for (x, y) in inputs.iter().zip(&rep.outputs) {
            let want = matvec(x, &w, p.simd_type).map_err(|e| e.to_string())?;
            if y != &want {
                return Err(format!("{p}: sim {y:?} != ref {want:?}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_cycle_formula_exact_without_stalls() {
    check("cycle-formula", Config::cases(60), |g| {
        let p = arb_params(g);
        let w = arb_weights(g, &p);
        let n = g.usize_in(1, 5);
        let inputs = arb_inputs(g, &p, n);
        let rep = run_mvu(&p, &w, &inputs).map_err(|e| e.to_string())?;
        let want = p.synapse_fold() * p.neuron_fold() * n + PIPELINE_STAGES + 1;
        if rep.exec_cycles != want {
            return Err(format!("{p} x{n}: {} cycles != formula {want}", rep.exec_cycles));
        }
        if rep.slots_consumed != p.synapse_fold() * p.neuron_fold() * n {
            return Err("slot count mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_no_data_loss_or_reorder_under_stalls() {
    check("stall-integrity", Config::cases(50), |g| {
        let p = arb_params(g);
        let w = arb_weights(g, &p);
        let n = g.usize_in(1, 4);
        let inputs = arb_inputs(g, &p, n);
        let in_stall = arb_stall(g);
        let out_stall = arb_stall(g);
        let rep = run_mvu_stalled(&p, &w, &inputs, in_stall.clone(), out_stall.clone())
            .map_err(|e| format!("{p} deadlocked ({in_stall:?}/{out_stall:?}): {e}"))?;
        if rep.outputs.len() != inputs.len() {
            return Err("output count mismatch".into());
        }
        for (x, y) in inputs.iter().zip(&rep.outputs) {
            let want = matvec(x, &w, p.simd_type).map_err(|e| e.to_string())?;
            if y != &want {
                return Err(format!("{p}: stalled sim diverged"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_stalls_only_add_cycles() {
    check("stalls-monotone", Config::cases(40), |g| {
        let p = arb_params(g);
        let w = arb_weights(g, &p);
        let inputs = arb_inputs(g, &p, 2);
        let clean = run_mvu(&p, &w, &inputs).map_err(|e| e.to_string())?;
        let stalled = run_mvu_stalled(
            &p,
            &w,
            &inputs,
            arb_stall(g),
            arb_stall(g),
        )
        .map_err(|e| e.to_string())?;
        if stalled.exec_cycles < clean.exec_cycles {
            return Err(format!(
                "stalled run faster ({} < {})",
                stalled.exec_cycles, clean.exec_cycles
            ));
        }
        Ok(())
    });
}

/// A "bursty" stall pattern that always eventually makes progress:
/// periodic bursts with duty < period (kept short so the deadlock bound
/// of `run_mvu_fifo` stays generous), bounded random stalls, or an
/// explicit schedule with at least one free slot.
fn arb_bursty_stall(g: &mut Gen) -> StallPattern {
    match g.usize_in(0, 2) {
        0 => {
            let period = g.usize_in(2, 8);
            let duty = g.usize_in(1, period - 1);
            StallPattern::Periodic { period, duty, phase: g.usize_in(0, 7) }
        }
        1 => StallPattern::Random { seed: g.rng.next_u64(), p_num: g.usize_in(1, 200) as u32 },
        _ => {
            let len = g.usize_in(2, 10);
            let mut s: Vec<bool> = (0..len).map(|_| g.chance(140)).collect();
            let free = g.usize_in(0, len - 1);
            s[free] = false;
            StallPattern::Schedule(s)
        }
    }
}

/// Draw a modest configuration for FIFO-depth properties (small folds so
/// even heavily stalled runs stay far from the deadlock bound).
fn arb_small_params(g: &mut Gen) -> ValidatedParams {
    let ty = *g.choose(&SimdType::ALL);
    let (wb, ib) = match ty {
        SimdType::Xnor => (1, 1),
        SimdType::BinaryWeights => (1, 2),
        SimdType::Standard => (2, 2),
    };
    let rows = g.usize_in(1, 12);
    let cols = g.usize_in(1, 32);
    let pe = g.divisor_of(rows);
    let simd = g.divisor_of(cols);
    DesignPoint::fc("fifo-prop")
        .in_features(cols)
        .out_features(rows)
        .pe(pe)
        .simd(simd)
        .simd_type(ty)
        .precision(wb, ib, 0)
        .build()
        .expect("generated folds are divisors, hence legal")
}

/// §5.3.2 liveness + integrity: for any FIFO depth >= 1 and bursty stall
/// patterns on both endpoints, the MVU completes (no deadlock), delivers
/// every output in order and bit-exact, consumes exactly SF*NF*n compute
/// slots, and never exceeds the FIFO's capacity.
#[test]
fn prop_fifo_liveness_and_integrity_under_bursts() {
    check("fifo-liveness", Config::cases(45), |g| {
        let p = arb_small_params(g);
        let w = arb_weights(g, &p);
        let n = g.usize_in(1, 3);
        let inputs = arb_inputs(g, &p, n);
        let depth = g.usize_in(1, 8);
        let in_stall = arb_bursty_stall(g);
        let out_stall = arb_bursty_stall(g);
        let rep = run_mvu_fifo(&p, &w, &inputs, in_stall.clone(), out_stall.clone(), depth)
            .map_err(|e| {
                format!("{p} depth={depth} ({in_stall:?}/{out_stall:?}): liveness lost: {e}")
            })?;
        if rep.outputs.len() != inputs.len() {
            return Err(format!("{}/{} outputs", rep.outputs.len(), inputs.len()));
        }
        for (x, y) in inputs.iter().zip(&rep.outputs) {
            let want = matvec(x, &w, p.simd_type).map_err(|e| e.to_string())?;
            if y != &want {
                return Err(format!("{p} depth={depth}: data corrupted under stalls"));
            }
        }
        let slots = p.synapse_fold() * p.neuron_fold() * n;
        if rep.slots_consumed != slots {
            return Err(format!(
                "slots {} != {slots} (lost or duplicated work)",
                rep.slots_consumed
            ));
        }
        if rep.fifo_max_occupancy > depth {
            return Err(format!("FIFO high-water {} > depth {depth}", rep.fifo_max_occupancy));
        }
        Ok(())
    });
}

/// §5.3.2 decoupling: with an always-valid source and a bursty sink, a
/// deeper output FIFO never stalls the datapath more, never finishes
/// later, and never changes the numerics.
#[test]
fn prop_deeper_fifo_never_stalls_more() {
    check("fifo-monotone", Config::cases(35), |g| {
        let p = arb_small_params(g);
        let w = arb_weights(g, &p);
        let n = g.usize_in(1, 4);
        let inputs = arb_inputs(g, &p, n);
        let out_stall = arb_bursty_stall(g);
        let shallow = g.usize_in(1, 4);
        let deep = shallow + g.usize_in(1, 12);
        let a = run_mvu_fifo(&p, &w, &inputs, StallPattern::None, out_stall.clone(), shallow)
            .map_err(|e| e.to_string())?;
        let b = run_mvu_fifo(&p, &w, &inputs, StallPattern::None, out_stall.clone(), deep)
            .map_err(|e| e.to_string())?;
        if b.stall_cycles > a.stall_cycles {
            return Err(format!(
                "{p} ({out_stall:?}): depth {deep} stalled {} > depth {shallow} stalled {}",
                b.stall_cycles, a.stall_cycles
            ));
        }
        if b.exec_cycles > a.exec_cycles {
            return Err(format!(
                "{p} ({out_stall:?}): depth {deep} took {} > depth {shallow} took {}",
                b.exec_cycles, a.exec_cycles
            ));
        }
        if a.outputs != b.outputs {
            return Err("FIFO depth changed the numerics".into());
        }
        Ok(())
    });
}

/// Regression (FIFO audit): a zero-depth output FIFO reachable through
/// the public API (`SimOptions::fifo_depth = 0`) must be a structured
/// error, not a `Fifo::new` panic.
#[test]
fn zero_fifo_depth_is_a_structured_error_not_a_panic() {
    let p = DesignPoint::fc("d0").in_features(8).out_features(4).pe(2).simd(4).build().unwrap();
    let w = Matrix::zeros(4, 8);
    let x: Vec<i32> = (0..8).collect();
    let err = run_mvu_fifo(&p, &w, &[x], StallPattern::None, StallPattern::None, 0)
        .expect_err("depth 0 must be rejected");
    assert!(err.to_string().contains("FIFO depth"), "{err:#}");
}

/// Regression (FIFO audit): depth-1 FIFO under a sink that is only ready
/// every third cycle — every transfer is a simultaneous pop-then-push at
/// full capacity. Data, ordering and the occupancy bound must all hold.
#[test]
fn depth1_fifo_simultaneous_push_pop_at_full_is_exact() {
    let p = DesignPoint::fc("d1").in_features(8).out_features(8).pe(4).simd(8).build().unwrap();
    let mut g = Gen::new(99, 16);
    let w = arb_weights(&mut g, &p);
    let inputs = arb_inputs(&mut g, &p, 6);
    let rep = run_mvu_fifo(
        &p,
        &w,
        &inputs,
        StallPattern::None,
        StallPattern::Periodic { period: 3, duty: 2, phase: 0 },
        1,
    )
    .unwrap();
    assert_eq!(rep.outputs.len(), inputs.len());
    for (x, y) in inputs.iter().zip(&rep.outputs) {
        assert_eq!(y, &matvec(x, &w, p.simd_type).unwrap());
    }
    assert_eq!(rep.fifo_max_occupancy, 1, "depth-1 high-water must be exactly its capacity");
    assert!(rep.stall_cycles > 0, "a depth-1 FIFO under a 2/3-stalled sink must stall");
}

/// Regression (input-buffer stall audit): deterministic stalls landing
/// mid-WRITE and mid-READ must leave the wr/rd pointers untouched so the
/// fill and the replay resume exactly where they stopped.
#[test]
fn write_and_read_phase_stalls_resume_exactly() {
    // SF = 4 words, NF = 4 folds: plenty of mid-fill and mid-replay cycles
    let p = DesignPoint::fc("stall").in_features(16).out_features(8).pe(2).simd(4).build().unwrap();
    let mut g = Gen::new(7, 16);
    let w = arb_weights(&mut g, &p);
    let inputs = arb_inputs(&mut g, &p, 3);
    // input gaps hit mid-WRITE; output stalls jam the pipe mid-READ
    let rep = run_mvu_fifo(
        &p,
        &w,
        &inputs,
        StallPattern::Schedule(vec![false, true, false, false, true]),
        StallPattern::Schedule(vec![true, false, true, true, false, false, true]),
        2,
    )
    .unwrap();
    assert_eq!(rep.outputs.len(), inputs.len());
    for (x, y) in inputs.iter().zip(&rep.outputs) {
        assert_eq!(y, &matvec(x, &w, p.simd_type).unwrap());
    }
    assert_eq!(
        rep.slots_consumed,
        p.synapse_fold() * p.neuron_fold() * inputs.len(),
        "a stalled replay must not repeat or drop compute slots"
    );
}

#[test]
fn prop_hls_model_agrees_with_rtl_sim() {
    check("hls==rtl-numerics", Config::cases(40), |g| {
        let p = arb_params(g);
        let w = arb_weights(g, &p);
        let n = g.usize_in(1, 3);
        let inputs = arb_inputs(g, &p, n);
        let rtl = run_mvu(&p, &w, &inputs).map_err(|e| e.to_string())?;
        let hls = HlsMvu::new(&p, &w)
            .and_then(|m| m.run(&inputs))
            .map_err(|e| e.to_string())?;
        if rtl.outputs != hls.outputs {
            return Err(format!("{p}: HLS model diverges from RTL sim"));
        }
        // both are II=1 machines; cycle counts within fill-latency slack
        if rtl.exec_cycles.abs_diff(hls.exec_cycles) > 2 {
            return Err(format!(
                "{p}: cycles RTL {} vs HLS {}",
                rtl.exec_cycles, hls.exec_cycles
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_bitpack_roundtrip() {
    use finn_mvu::quant::{pack_bits, unpack_bits};
    check("bitpack-roundtrip", Config::cases(80), |g| {
        let bits = *g.choose(&[1u32, 2, 4, 8, 16]);
        let n = g.usize_in(0, 64);
        let signed = bits > 1 && g.chance(128);
        let lanes: Vec<i32> = if signed {
            let half = 1i32 << (bits - 1);
            g.vec_i32(n, -half, half - 1)
        } else {
            g.vec_i32(n, 0, (1i32 << bits.min(16)) - 1)
        };
        let bv = pack_bits(&lanes, bits);
        let back = unpack_bits(&bv, bits, signed);
        if back != lanes {
            return Err(format!("roundtrip {bits}bit signed={signed}: {lanes:?} -> {back:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip() {
    use finn_mvu::util::json::Json;
    fn arb_json(g: &mut Gen, depth: usize) -> Json {
        match if depth == 0 { g.usize_in(0, 3) } else { g.usize_in(0, 5) } {
            0 => Json::Null,
            1 => Json::Bool(g.chance(128)),
            2 => Json::from_i64(g.i32_in(-100000, 100000) as i64),
            3 => Json::Str(
                (0..g.usize_in(0, 8))
                    .map(|_| *g.choose(&['a', 'ß', '"', '\\', '\n', 'é', 'x']))
                    .collect(),
            ),
            4 => Json::Arr((0..g.usize_in(0, 4)).map(|_| arb_json(g, depth - 1)).collect()),
            _ => {
                let mut m = std::collections::BTreeMap::new();
                for i in 0..g.usize_in(0, 4) {
                    m.insert(format!("k{i}"), arb_json(g, depth - 1));
                }
                Json::Obj(m)
            }
        }
    }
    check("json-roundtrip", Config::cases(100), |g| {
        let v = arb_json(g, 3);
        let compact = Json::parse(&v.to_string()).map_err(|e| e.to_string())?;
        let pretty = Json::parse(&v.to_pretty(2)).map_err(|e| e.to_string())?;
        if compact != v || pretty != v {
            return Err(format!("roundtrip failed for {v}"));
        }
        Ok(())
    });
}

#[test]
fn prop_chain_matches_layerwise_reference() {
    use finn_mvu::quant::{multithreshold, Thresholds};
    use finn_mvu::sim::MvuChain;
    check("chain==ref", Config::cases(25), |g| {
        // 2-3 chained FC layers with random (legal) folds and optional
        // thresholds between layers
        let n_layers = g.usize_in(2, 3);
        let mut dims = vec![g.usize_in(2, 24)];
        for _ in 0..n_layers {
            dims.push(g.usize_in(1, 12));
        }
        let mut layers = Vec::new();
        for i in 0..n_layers {
            let (fin, fout) = (dims[i], dims[i + 1]);
            let pe = g.divisor_of(fout);
            let simd = g.divisor_of(fin);
            let with_th = i + 1 < n_layers; // inner layers threshold
            let p = DesignPoint::fc(&format!("c{i}"))
                .in_features(fin)
                .out_features(fout)
                .pe(pe)
                .simd(simd)
                .precision(2, 2, if with_th { 2 } else { 0 })
                .build()
                .expect("generated folds are divisors, hence legal");
            let w = arb_weights(g, &p);
            let th = with_th.then(|| {
                Thresholds::from_rows(
                    &(0..fout)
                        .map(|_| {
                            let mut t = g.vec_i32(3, -20, 20);
                            t.sort();
                            t
                        })
                        .collect::<Vec<_>>(),
                )
                .unwrap()
            });
            layers.push((p, w, th));
        }
        let inputs: Vec<Vec<i32>> =
            (0..g.usize_in(1, 4)).map(|_| g.vec_i32(dims[0], 0, 3)).collect();
        let mut chain = MvuChain::new(&layers).map_err(|e| e.to_string())?;
        let rep = chain.run(&inputs).map_err(|e| e.to_string())?;
        for (x, y) in inputs.iter().zip(&rep.outputs) {
            let mut v = x.clone();
            for (p, w, th) in &layers {
                let acc = matvec(&v, w, p.simd_type).map_err(|e| e.to_string())?;
                v = match th {
                    Some(t) => multithreshold(&acc, t).map_err(|e| e.to_string())?,
                    None => acc,
                };
            }
            if y != &v {
                return Err("chain diverged from layer-wise reference".into());
            }
        }
        Ok(())
    });
}
